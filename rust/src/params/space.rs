//! The parameter space: named multi-valued parameters, fixed (zip)
//! clauses, Cartesian enumeration, and index-addressable combinations.
//!
//! Axes (§5.1): parameters NOT in any fixed clause each form their own
//! axis; every fixed clause forms ONE axis whose length is the common
//! value count of its members ("ordered one-to-one mappings"). The total
//! workflow count is the product of axis lengths:
//!
//!   N_W = Π_i N_i           (no fixed clauses)
//!   W   = { W_1 × W_2 }     (W_2 = the zipped fixed parameters)

use super::value::Value;
use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// A named, multi-valued parameter. Names are scoped paths like
/// `matmulOMP:args:size` or `matmulOMP:environ:OMP_NUM_THREADS` (the
/// interpolation engine resolves `${...}` references against them).
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    /// Fully-scoped parameter name.
    pub name: String,
    /// The parameter's values, in declaration order.
    pub values: Vec<Value>,
}

impl Param {
    /// Construct from raw strings.
    pub fn new(name: impl Into<String>, values: Vec<String>) -> Param {
        Param {
            name: name.into(),
            values: values.into_iter().map(Value::new).collect(),
        }
    }
}

/// One enumerated combination: parameter name → chosen value.
pub type Combination = BTreeMap<String, Value>;

/// An axis of the enumeration: an independent parameter or a zipped
/// fixed group.
#[derive(Debug, Clone)]
enum Axis {
    /// Independent parameter (index into `Space::params`).
    Single(usize),
    /// Fixed clause: all listed parameters step together.
    Zip(Vec<usize>),
}

/// A fully-specified parameter space.
#[derive(Debug, Clone)]
pub struct Space {
    params: Vec<Param>,
    axes: Vec<Axis>,
}

impl Space {
    /// Build a space. `fixed_clauses` lists, per clause, the names of the
    /// parameters zipped together. Errors on: unknown names, a parameter
    /// in two clauses, arity mismatch within a clause, empty value lists.
    pub fn new(params: Vec<Param>, fixed_clauses: &[Vec<String>]) -> Result<Space> {
        for p in &params {
            if p.values.is_empty() {
                return Err(Error::Params(format!(
                    "parameter '{}' has no values",
                    p.name
                )));
            }
        }
        let index: BTreeMap<&str, usize> = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.as_str(), i))
            .collect();
        if index.len() != params.len() {
            return Err(Error::Params("duplicate parameter name".into()));
        }

        let mut in_clause = vec![false; params.len()];
        let mut axes = Vec::new();
        for clause in fixed_clauses {
            let mut members = Vec::new();
            for name in clause {
                let &i = index.get(name.as_str()).ok_or_else(|| {
                    Error::Params(format!(
                        "fixed clause references unknown parameter '{name}'"
                    ))
                })?;
                if in_clause[i] {
                    return Err(Error::Params(format!(
                        "parameter '{name}' appears in more than one fixed clause"
                    )));
                }
                in_clause[i] = true;
                members.push(i);
            }
            if members.is_empty() {
                return Err(Error::Params("empty fixed clause".into()));
            }
            let n0 = params[members[0]].values.len();
            for &m in &members[1..] {
                let n = params[m].values.len();
                if n != n0 {
                    return Err(Error::Params(format!(
                        "fixed clause arity mismatch: '{}' has {} values, '{}' has {}",
                        params[members[0]].name, n0, params[m].name, n
                    )));
                }
            }
            axes.push(Axis::Zip(members));
        }
        // Independent parameters, in declaration order, become the inner
        // axes; fixed clauses are outermost (§5.1: "moving all the fixed
        // parameters into the outermost loop structures").
        for (i, _) in params.iter().enumerate() {
            if !in_clause[i] {
                axes.push(Axis::Single(i));
            }
        }
        Ok(Space { params, axes })
    }

    /// Space with no fixed clauses.
    pub fn cartesian(params: Vec<Param>) -> Result<Space> {
        Space::new(params, &[])
    }

    /// All parameters (declaration order).
    pub fn params(&self) -> &[Param] {
        &self.params
    }

    /// Total number of combinations N_W.
    pub fn len(&self) -> u64 {
        self.axes
            .iter()
            .map(|a| self.axis_len(a) as u64)
            .product()
    }

    /// True when the space has no axes (no parameters → one empty combo
    /// by convention, so `is_empty` is about *parameters*).
    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    fn axis_len(&self, a: &Axis) -> usize {
        match a {
            Axis::Single(i) => self.params[*i].values.len(),
            Axis::Zip(ms) => self.params[ms[0]].values.len(),
        }
    }

    /// Number of axes (independent parameters + one per fixed clause).
    pub fn n_axes(&self) -> usize {
        self.axes.len()
    }

    /// For each parameter (declaration order), the axis whose digit
    /// selects its value. Zipped parameters map to their shared axis.
    pub fn param_axes(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.params.len()];
        for (a, axis) in self.axes.iter().enumerate() {
            match axis {
                Axis::Single(i) => out[*i] = a,
                Axis::Zip(ms) => {
                    for &m in ms {
                        out[m] = a;
                    }
                }
            }
        }
        out
    }

    /// Mixed-radix decode of combination `idx` into per-axis digits
    /// (last axis varies fastest — the nested-loop order in §5.1). The
    /// compiled materialization pipeline works directly on these digits;
    /// [`Space::combination`] expands them into a string-keyed map.
    pub fn digits(&self, idx: u64) -> Result<Vec<u32>> {
        let total = self.len();
        if idx >= total {
            return Err(Error::Params(format!(
                "combination index {idx} out of range (total {total})"
            )));
        }
        let mut rem = idx;
        let mut digits = vec![0u32; self.axes.len()];
        for (d, axis) in self.axes.iter().enumerate().rev() {
            let n = self.axis_len(axis) as u64;
            digits[d] = (rem % n) as u32;
            rem /= n;
        }
        Ok(digits)
    }

    /// Length of every enumeration axis, in axis order (the mixed radix
    /// of [`Space::digits`] / [`Space::index_of_digits`]).
    pub fn axis_lens(&self) -> Vec<usize> {
        self.axes.iter().map(|a| self.axis_len(a)).collect()
    }

    /// Mixed-radix compose — the inverse of [`Space::digits`]: per-axis
    /// `digits` back to the global combination index. Errors on arity
    /// mismatch or an out-of-range digit. O(#axes), independent of the
    /// space size, so adaptive search strategies can address neighbors
    /// of a combination without enumerating anything.
    pub fn index_of_digits(&self, digits: &[u32]) -> Result<u64> {
        if digits.len() != self.axes.len() {
            return Err(Error::Params(format!(
                "digit vector has {} entries, space has {} axes",
                digits.len(),
                self.axes.len()
            )));
        }
        let mut idx = 0u64;
        for (a, (axis, &d)) in self.axes.iter().zip(digits).enumerate() {
            let n = self.axis_len(axis) as u64;
            if d as u64 >= n {
                return Err(Error::Params(format!(
                    "digit {d} out of range for axis {a} (length {n})"
                )));
            }
            idx = idx * n + d as u64;
        }
        Ok(idx)
    }

    /// Expand per-axis `digits` into an owned name → value map.
    pub fn combination_from_digits(&self, digits: &[u32]) -> Combination {
        let mut combo = Combination::new();
        for (axis, &digit) in self.axes.iter().zip(digits) {
            let digit = digit as usize;
            match axis {
                Axis::Single(i) => {
                    let p = &self.params[*i];
                    combo.insert(p.name.clone(), p.values[digit].clone());
                }
                Axis::Zip(ms) => {
                    for &m in ms {
                        let p = &self.params[m];
                        combo.insert(p.name.clone(), p.values[digit].clone());
                    }
                }
            }
        }
        combo
    }

    /// Decode combination `idx` (0-based, row-major over axes: the LAST
    /// axis varies fastest — matching the nested-loop order in §5.1).
    pub fn combination(&self, idx: u64) -> Result<Combination> {
        Ok(self.combination_from_digits(&self.digits(idx)?))
    }

    /// Iterate all combinations in order — a lazy cursor; nothing is
    /// materialized up front.
    pub fn iter(&self) -> Combinations<'_> {
        self.combinations()
    }

    /// Lazy cursor over every combination (index order).
    pub fn combinations(&self) -> Combinations<'_> {
        Combinations { space: self, next: 0, end: self.len() }
    }

    /// Lazy cursor over the index range `start..end` (clamped to the
    /// space). Each step is one O(#axes) mixed-radix decode; skipping is
    /// O(1) because combinations are index-addressed.
    pub fn combinations_range(&self, start: u64, end: u64) -> Combinations<'_> {
        let total = self.len();
        let end = end.min(total);
        Combinations { space: self, next: start.min(end), end }
    }
}

/// Streaming cursor over a contiguous index range of a [`Space`] — the
/// iterator behind [`Space::iter`]. Holds O(1) state: decoding happens
/// per `next()` call via [`Space::combination`].
#[derive(Debug, Clone)]
pub struct Combinations<'a> {
    space: &'a Space,
    next: u64,
    end: u64,
}

impl Iterator for Combinations<'_> {
    type Item = Combination;

    fn next(&mut self) -> Option<Combination> {
        if self.next >= self.end {
            return None;
        }
        let c = self
            .space
            .combination(self.next)
            .expect("index < len is always decodable");
        self.next += 1;
        Some(c)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = (self.end - self.next) as usize;
        (n, Some(n))
    }

    fn nth(&mut self, n: usize) -> Option<Combination> {
        // index addressing makes skipping free — no decode per skipped
        // combination (clamped so `len()` never underflows)
        self.next = self.next.saturating_add(n as u64).min(self.end);
        self.next()
    }
}

impl ExactSizeIterator for Combinations<'_> {}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(name: &str, vals: &[&str]) -> Param {
        Param::new(name, vals.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn paper_matmul_space_is_88() {
        // Figure 6: 11 sizes × 8 threads = 88 workflow instances.
        let space = Space::cartesian(vec![
            p("environ:OMP_NUM_THREADS", &["1", "2", "3", "4", "5", "6", "7", "8"]),
            p("args:size", &[
                "16", "32", "64", "128", "256", "512", "1024", "2048",
                "4096", "8192", "16384",
            ]),
        ])
        .unwrap();
        assert_eq!(space.len(), 88);
        let all: Vec<_> = space.iter().collect();
        assert_eq!(all.len(), 88);
        // every combination is unique
        let mut dedup = all.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 88);
    }

    #[test]
    fn last_axis_varies_fastest() {
        let space = Space::cartesian(vec![
            p("a", &["1", "2"]),
            p("b", &["x", "y", "z"]),
        ])
        .unwrap();
        let combos: Vec<_> = space.iter().collect();
        assert_eq!(combos[0]["a"].as_str(), "1");
        assert_eq!(combos[0]["b"].as_str(), "x");
        assert_eq!(combos[1]["b"].as_str(), "y");
        assert_eq!(combos[3]["a"].as_str(), "2");
        assert_eq!(combos[3]["b"].as_str(), "x");
    }

    #[test]
    fn fixed_clause_zips() {
        // §5.1 example: P2 and P3 in the same fixed clause.
        let space = Space::new(
            vec![
                p("p1", &["a", "b"]),
                p("p2", &["1", "2", "3"]),
                p("p3", &["x", "y", "z"]),
            ],
            &[vec!["p2".into(), "p3".into()]],
        )
        .unwrap();
        // N = 2 * 3 (not 2 * 3 * 3)
        assert_eq!(space.len(), 6);
        for c in space.iter() {
            // bijection: p2=1 ⇔ p3=x, etc.
            let i = c["p2"].as_i64().unwrap() as usize - 1;
            assert_eq!(c["p3"].as_str(), ["x", "y", "z"][i]);
        }
    }

    #[test]
    fn fixed_single_param_is_constant_axis() {
        // "can be used to specify constant single-valued parameters"
        let space = Space::new(
            vec![p("const", &["42"]), p("v", &["1", "2"])],
            &[vec!["const".into()]],
        )
        .unwrap();
        assert_eq!(space.len(), 2);
        for c in space.iter() {
            assert_eq!(c["const"].as_str(), "42");
        }
    }

    #[test]
    fn multiple_fixed_clauses() {
        let space = Space::new(
            vec![
                p("a", &["1", "2"]),
                p("b", &["u", "v"]),
                p("c", &["8", "9"]),
                p("d", &["p", "q"]),
            ],
            &[
                vec!["a".into(), "b".into()],
                vec!["c".into(), "d".into()],
            ],
        )
        .unwrap();
        assert_eq!(space.len(), 4); // 2 (a,b zipped) × 2 (c,d zipped)
    }

    #[test]
    fn errors() {
        assert!(Space::cartesian(vec![p("e", &[])]).is_err());
        assert!(Space::new(
            vec![p("a", &["1"]), p("b", &["1", "2"])],
            &[vec!["a".into(), "b".into()]],
        )
        .is_err()); // arity mismatch
        assert!(Space::new(vec![p("a", &["1"])], &[vec!["zz".into()]]).is_err());
        assert!(Space::new(
            vec![p("a", &["1"]), p("b", &["1"])],
            &[vec!["a".into()], vec!["a".into(), "b".into()]],
        )
        .is_err()); // a in two clauses
        assert!(
            Space::cartesian(vec![p("a", &["1"]), p("a", &["2"])]).is_err()
        ); // duplicate name
    }

    #[test]
    fn empty_space_has_one_empty_combination() {
        let space = Space::cartesian(vec![]).unwrap();
        assert_eq!(space.len(), 1);
        assert!(space.combination(0).unwrap().is_empty());
    }

    #[test]
    fn cursor_is_lazy_and_skippable() {
        let space = Space::cartesian(vec![
            p("a", &["1", "2", "3"]),
            p("b", &["x", "y"]),
        ])
        .unwrap();
        let mut it = space.combinations();
        assert_eq!(it.len(), 6);
        let c = it.nth(4).unwrap(); // index 4 = a=3, b=x
        assert_eq!(c["a"].as_str(), "3");
        assert_eq!(c["b"].as_str(), "x");
        assert_eq!(it.len(), 1);
        // range cursor, clamped
        let tail: Vec<_> = space.combinations_range(4, 100).collect();
        assert_eq!(tail.len(), 2);
        assert!(space.combinations_range(9, 12).next().is_none());
    }

    #[test]
    fn index_of_digits_inverts_digits() {
        let space = Space::new(
            vec![
                p("a", &["1", "2", "3"]),
                p("b", &["x", "y"]),
                p("c", &["7", "8", "9", "10"]),
                p("d", &["u", "v"]),
            ],
            &[vec!["b".into(), "d".into()]],
        )
        .unwrap();
        assert_eq!(space.axis_lens(), vec![2, 3, 4]); // zip axis first
        for idx in 0..space.len() {
            let digits = space.digits(idx).unwrap();
            assert_eq!(space.index_of_digits(&digits).unwrap(), idx);
        }
        // arity + range errors
        assert!(space.index_of_digits(&[0, 0]).is_err());
        assert!(space.index_of_digits(&[0, 3, 0]).is_err());
    }

    #[test]
    fn combination_index_round_trip() {
        let space = Space::cartesian(vec![
            p("a", &["1", "2", "3"]),
            p("b", &["x", "y"]),
            p("c", &["7", "8", "9", "10"]),
        ])
        .unwrap();
        let seq: Vec<_> = space.iter().collect();
        for (i, c) in seq.iter().enumerate() {
            assert_eq!(&space.combination(i as u64).unwrap(), c);
        }
        assert!(space.combination(space.len()).is_err());
    }
}
