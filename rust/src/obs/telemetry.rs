//! Per-attempt resource telemetry: a `/proc/<pid>` sampler.
//!
//! The runner's timeout poll loop already wakes up every few hundred
//! microseconds to `try_wait` the child; this module piggybacks on
//! those wakeups to read `/proc/<pid>/{stat,statm,io}` and accumulate
//! four per-attempt resource measurements next to `wall_time`:
//!
//! * `cpu_secs` — user + system CPU time (utime + stime ticks / the
//!   standard Linux `USER_HZ` of 100),
//! * `max_rss_kb` — the largest resident set observed across samples,
//! * `io_read_bytes` / `io_write_bytes` — storage-layer I/O counters
//!   (`read_bytes`/`write_bytes` from `/proc/<pid>/io`).
//!
//! **Portability**: the sampler is strictly best-effort. Off Linux (no
//! `/proc`), on read failure, on parse failure, or when the child exits
//! before the first poll, the affected fields stay 0 and nothing else
//! changes — the measurements are a bonus, never a dependency. Values
//! are read from the live process, so the final datum is the *last
//! successful sample* before the child was reaped; a task shorter than
//! one poll interval records zeros.

/// One attempt's sampled resource consumption (all zeros when the
/// sampler never got a successful read — see module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceUsage {
    /// User + system CPU seconds.
    pub cpu_secs: f64,
    /// Peak resident set size in KiB.
    pub max_rss_kb: u64,
    /// Bytes read from the storage layer.
    pub io_read_bytes: u64,
    /// Bytes written to the storage layer.
    pub io_write_bytes: u64,
}

/// Linux `USER_HZ`: `/proc/<pid>/stat` utime/stime are in these ticks.
/// Fixed at 100 on every Linux ABI; without libc we cannot ask
/// `sysconf(_SC_CLK_TCK)`, and 100 is correct wherever `/proc` exists.
const CLOCK_TICKS_PER_SEC: f64 = 100.0;

/// Page size assumed for `/proc/<pid>/statm` resident pages. 4 KiB on
/// every mainstream Linux target this crate builds for.
const PAGE_KB: u64 = 4;

/// Polls `/proc/<pid>` for one child process and accumulates a
/// [`ResourceUsage`]. Construct after spawn, call [`sample`] from the
/// wait loop, take the result with [`finish`] after reaping.
///
/// [`sample`]: ResourceSampler::sample
/// [`finish`]: ResourceSampler::finish
#[derive(Debug)]
pub struct ResourceSampler {
    /// `/proc/<pid>` for the sampled child; `None` when the first probe
    /// found no readable proc entry (non-Linux) — every later sample is
    /// then a no-op.
    proc_dir: Option<std::path::PathBuf>,
    usage: ResourceUsage,
}

impl ResourceSampler {
    /// Attach to a live child process. Probes `/proc/<pid>/stat` once;
    /// when unreadable the sampler permanently degrades to a no-op.
    pub fn attach(pid: u32) -> ResourceSampler {
        let dir = std::path::PathBuf::from(format!("/proc/{pid}"));
        let proc_dir = if dir.join("stat").is_file() { Some(dir) } else { None };
        ResourceSampler { proc_dir, usage: ResourceUsage::default() }
    }

    /// Take one sample (cheap: up to three small `/proc` reads). CPU and
    /// I/O counters are monotone in the kernel, so keeping the latest
    /// successful read is exact; RSS keeps the running maximum.
    pub fn sample(&mut self) {
        let Some(dir) = &self.proc_dir else { return };
        if let Some(cpu) = read_cpu_secs(&dir.join("stat")) {
            self.usage.cpu_secs = cpu;
        }
        if let Some(rss) = read_rss_kb(&dir.join("statm")) {
            self.usage.max_rss_kb = self.usage.max_rss_kb.max(rss);
        }
        if let Some((r, w)) = read_io_bytes(&dir.join("io")) {
            self.usage.io_read_bytes = r;
            self.usage.io_write_bytes = w;
        }
    }

    /// The accumulated usage (call after the child was reaped; takes a
    /// final sample first in case the loop never polled).
    pub fn finish(mut self) -> ResourceUsage {
        self.sample();
        self.usage
    }
}

/// `utime + stime` seconds from a `/proc/<pid>/stat` line. The comm
/// field `(...)` may itself contain spaces or parens, so fields are
/// counted from after the *last* `)`: the first token after it is field
/// 3 (`state`); `utime`/`stime` are fields 14/15 of the full line.
fn read_cpu_secs(path: &std::path::Path) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let rest = &text[text.rfind(')')? + 1..];
    let mut fields = rest.split_whitespace();
    let utime: u64 = fields.nth(11)?.parse().ok()?;
    let stime: u64 = fields.next()?.parse().ok()?;
    Some((utime + stime) as f64 / CLOCK_TICKS_PER_SEC)
}

/// Resident set in KiB from `/proc/<pid>/statm` (field 2, in pages).
fn read_rss_kb(path: &std::path::Path) -> Option<u64> {
    let text = std::fs::read_to_string(path).ok()?;
    let pages: u64 = text.split_whitespace().nth(1)?.parse().ok()?;
    Some(pages * PAGE_KB)
}

/// `(read_bytes, write_bytes)` from `/proc/<pid>/io`.
fn read_io_bytes(path: &std::path::Path) -> Option<(u64, u64)> {
    let text = std::fs::read_to_string(path).ok()?;
    let field = |name: &str| -> Option<u64> {
        text.lines()
            .find_map(|l| l.strip_prefix(name)?.strip_prefix(':'))
            .and_then(|v| v.trim().parse().ok())
    };
    Some((field("read_bytes")?, field("write_bytes")?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join("papas_telemetry").join(tag);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn stat_cpu_parses_past_hostile_comm_names() {
        let d = tmp("stat");
        let p = d.join("stat");
        // comm contains spaces and a closing paren — fields must be
        // counted from the *last* ')'
        std::fs::write(
            &p,
            "1234 (my (we) ird) S 1 1 1 0 -1 4194304 100 0 0 0 250 50 0 0 \
             20 0 1 0 12345 1000000 500 18446744073709551615",
        )
        .unwrap();
        // utime=250 stime=50 ticks at 100 Hz → 3.0s
        assert_eq!(read_cpu_secs(&p), Some(3.0));
    }

    #[test]
    fn statm_and_io_parse() {
        let d = tmp("statm_io");
        std::fs::write(d.join("statm"), "2000 512 300 10 0 400 0\n").unwrap();
        assert_eq!(read_rss_kb(&d.join("statm")), Some(2048));
        std::fs::write(
            d.join("io"),
            "rchar: 999\nwchar: 888\nsyscr: 10\nsyscw: 5\n\
             read_bytes: 4096\nwrite_bytes: 8192\ncancelled_write_bytes: 0\n",
        )
        .unwrap();
        assert_eq!(read_io_bytes(&d.join("io")), Some((4096, 8192)));
    }

    #[test]
    fn malformed_files_yield_none() {
        let d = tmp("bad");
        std::fs::write(d.join("stat"), "not a stat line").unwrap();
        assert_eq!(read_cpu_secs(&d.join("stat")), None);
        std::fs::write(d.join("statm"), "").unwrap();
        assert_eq!(read_rss_kb(&d.join("statm")), None);
        std::fs::write(d.join("io"), "rchar: 1\n").unwrap();
        assert_eq!(read_io_bytes(&d.join("io")), None);
        assert_eq!(read_cpu_secs(&d.join("ghost")), None);
    }

    #[test]
    fn sampler_degrades_to_noop_without_proc_entry() {
        // PID u32::MAX cannot exist — attach must not panic and finish
        // must return zeros
        let s = ResourceSampler::attach(u32::MAX);
        let u = s.finish();
        assert_eq!(u, ResourceUsage::default());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn sampler_reads_a_live_process() {
        // sample our own process: RSS must be nonzero on Linux
        let mut s = ResourceSampler::attach(std::process::id());
        s.sample();
        let u = s.finish();
        assert!(u.max_rss_kb > 0, "{u:?}");
    }
}
