//! Critical-path analysis and bottleneck attribution (`papas doctor`).
//!
//! Folds a run's trace journal (untyped [`Json`] events from
//! [`super::read_trace`]) together with the compiled task [`Dag`] into a
//! [`Diagnosis`]:
//!
//! * **per-instance critical paths** — a forward/backward longest-path
//!   pass over final-attempt durations yields the critical chain, its
//!   length versus the instance's observed span, and per-task slack;
//! * **run-level attribution** — the run's worker-seconds budget
//!   (makespan × workers) partitioned *exactly* into five buckets:
//!   critical-path compute, off-critical compute, retry/backoff waste,
//!   scheduler overhead (workers idle while dispatched work waited),
//!   and genuine idle (the remainder — no ready work existed);
//! * **what-if table** — a greedy list-schedule replay (the
//!   earliest-free-lane technique from the scheduler-packing bench,
//!   extended with DAG readiness) re-run once per task with that task's
//!   durations halved, answering "task X 2× faster ⇒ makespan −N%".
//!
//! Everything here is a pure function of the journal + DAG: two calls
//! over the same inputs produce byte-identical `--format json` output,
//! which the golden e2e test relies on.

use crate::json::Json;
use crate::workflow::{CostModel, Dag};
use std::collections::{BTreeMap, BTreeSet};

/// One parsed `complete` event (a single attempt).
#[derive(Debug, Clone)]
struct Attempt {
    task: usize,
    instance: u64,
    attempt: i64,
    ok: bool,
    duration: f64,
    start: f64,
    end: f64,
    cpu_secs: f64,
    max_rss_kb: f64,
}

/// Critical-path report for one workflow instance.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceDiagnosis {
    /// Workflow instance index.
    pub instance: u64,
    /// Observed span: latest attempt end − earliest attempt start.
    pub span: f64,
    /// Length of the critical path (sum of its final-attempt durations).
    pub critical_len: f64,
    /// Task ids along the critical path, in execution order.
    pub critical_path: Vec<String>,
    /// Per-task slack in seconds (0.0 for tasks on the critical path),
    /// keyed by task id.
    pub slack: BTreeMap<String, f64>,
}

/// Aggregate statistics for one task id across all instances.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDiagnosis {
    /// Task id.
    pub task_id: String,
    /// Final attempts observed.
    pub n: usize,
    /// Total final-attempt seconds.
    pub total_secs: f64,
    /// Mean final-attempt seconds.
    pub mean_secs: f64,
    /// Instances whose critical path contains this task.
    pub on_critical: usize,
    /// Mean slack across analyzed instances.
    pub mean_slack: f64,
    /// Mean sampled CPU seconds (0.0 when unsampled).
    pub mean_cpu_secs: f64,
    /// Mean sampled peak RSS in KiB (0.0 when unsampled).
    pub mean_rss_kb: f64,
}

/// The five-way exact partition of the run's worker-seconds budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Attribution {
    /// Makespan × workers: every worker-second the run paid for.
    pub total_worker_secs: f64,
    /// Final-attempt compute on instance critical paths.
    pub critical_compute: f64,
    /// Final-attempt compute off the critical paths.
    pub other_compute: f64,
    /// Failed-attempt compute plus retry backoff sleeps.
    pub retry_waste: f64,
    /// Worker-seconds idle while dispatched work sat in the ready
    /// queue (scheduler/executor starvation).
    pub scheduler_overhead: f64,
    /// The remainder: workers idle with no ready work (DAG barriers,
    /// tail of the run). Defined as total − the other four buckets, so
    /// the partition sums exactly.
    pub idle: f64,
}

/// One row of the what-if table: the replayed makespan if `task_id`
/// ran 2× faster.
#[derive(Debug, Clone, PartialEq)]
pub struct WhatIf {
    /// Task id whose durations were halved.
    pub task_id: String,
    /// Replayed baseline makespan (observed durations).
    pub baseline: f64,
    /// Replayed makespan with the task 2× faster.
    pub scaled: f64,
    /// Improvement as a percentage of the baseline.
    pub speedup_pct: f64,
}

/// The full `papas doctor` report.
#[derive(Debug, Clone)]
pub struct Diagnosis {
    /// Provenance run id (from the journal header).
    pub run: u32,
    /// Study name (from the journal header).
    pub study: String,
    /// Executor worker count (from the journal header).
    pub workers: usize,
    /// Observed run makespan: the latest attempt end offset.
    pub makespan: f64,
    /// Worker-seconds partition.
    pub attribution: Attribution,
    /// Per-instance critical paths, sorted by instance index.
    pub instances: Vec<InstanceDiagnosis>,
    /// Per-task aggregates, sorted by task id.
    pub tasks: Vec<TaskDiagnosis>,
    /// What-if rows, best improvement first.
    pub what_if: Vec<WhatIf>,
    /// Advisory findings (e.g. memory-budget violations).
    pub warnings: Vec<String>,
}

fn f(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

fn i(j: &Json, key: &str) -> i64 {
    j.get(key).and_then(Json::as_i64).unwrap_or(0)
}

/// Diagnose one run: fold `events` (a journal read back via
/// [`super::read_trace`]) against the study's compiled task `dag`.
///
/// The same task-level DAG is applied to every instance — task ids and
/// `after:` edges are fixed by the study spec, so the shape is shared.
pub fn diagnose(events: &[Json], dag: &Dag) -> Diagnosis {
    let mut run = 0u32;
    let mut study = String::new();
    let mut workers = 1usize;
    let mut attempts: Vec<Attempt> = Vec::new();
    let mut dispatch_ts: Vec<f64> = Vec::new();
    let mut dispatch_order: Vec<(usize, u64)> = Vec::new();
    let mut dispatched_keys: BTreeSet<String> = BTreeSet::new();
    let mut backoff_secs = 0.0f64;

    for ev in events {
        match ev.get("ev").and_then(Json::as_str).unwrap_or("") {
            "header" => {
                run = i(ev, "run") as u32;
                study = ev
                    .get("study")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                workers = (i(ev, "workers").max(1)) as usize;
            }
            "dispatch" => {
                dispatch_ts.push(f(ev, "ts"));
                let key =
                    ev.get("key").and_then(Json::as_str).unwrap_or("");
                if dispatched_keys.insert(key.to_string()) {
                    let task_id = key.split('#').next().unwrap_or("");
                    if let Some(t) = dag.index_of(task_id) {
                        dispatch_order.push((t, i(ev, "instance") as u64));
                    }
                }
            }
            "complete" => {
                let task_id =
                    ev.get("task_id").and_then(Json::as_str).unwrap_or("");
                let Some(task) = dag.index_of(task_id) else { continue };
                attempts.push(Attempt {
                    task,
                    instance: i(ev, "instance") as u64,
                    attempt: i(ev, "attempt"),
                    ok: ev
                        .get("ok")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                    duration: f(ev, "duration"),
                    start: f(ev, "start"),
                    end: f(ev, "end"),
                    cpu_secs: f(ev, "cpu_secs"),
                    max_rss_kb: i(ev, "max_rss_kb") as f64,
                });
            }
            "retry" => backoff_secs += i(ev, "backoff_ms") as f64 / 1000.0,
            _ => {}
        }
    }

    let makespan =
        attempts.iter().map(|a| a.end).fold(0.0f64, f64::max);

    // Final attempt per (task, instance): highest attempt number wins.
    let mut finals: BTreeMap<(usize, u64), &Attempt> = BTreeMap::new();
    for a in &attempts {
        let slot = finals.entry((a.task, a.instance)).or_insert(a);
        if a.attempt > slot.attempt {
            *slot = a;
        }
    }

    let topo = dag.topo_order().unwrap_or_default();
    let instance_ids: BTreeSet<u64> =
        finals.keys().map(|&(_, inst)| inst).collect();
    let mut instances: Vec<InstanceDiagnosis> = Vec::new();
    let mut on_path: BTreeSet<(usize, u64)> = BTreeSet::new();
    for &inst in &instance_ids {
        let diag = diagnose_instance(dag, &topo, &finals, inst, &attempts);
        for id in &diag.critical_path {
            if let Some(t) = dag.index_of(id) {
                on_path.insert((t, inst));
            }
        }
        instances.push(diag);
    }

    // --- attribution -------------------------------------------------
    let mut critical_compute = 0.0;
    let mut other_compute = 0.0;
    let mut retry_waste = backoff_secs;
    for a in &attempts {
        if !a.ok {
            retry_waste += a.duration;
        } else if on_path.contains(&(a.task, a.instance)) {
            critical_compute += a.duration;
        } else {
            other_compute += a.duration;
        }
    }
    let scheduler_overhead =
        starvation_secs(&attempts, &dispatch_ts, workers, makespan);
    let total_worker_secs = makespan * workers as f64;
    let attribution = Attribution {
        total_worker_secs,
        critical_compute,
        other_compute,
        retry_waste,
        scheduler_overhead,
        idle: total_worker_secs
            - critical_compute
            - other_compute
            - retry_waste
            - scheduler_overhead,
    };

    // --- per-task aggregates -----------------------------------------
    let mut tasks: Vec<TaskDiagnosis> = Vec::new();
    for t in 0..dag.len() {
        let mut n = 0usize;
        let (mut total, mut cpu, mut rss) = (0.0f64, 0.0f64, 0.0f64);
        let mut crit = 0usize;
        for (&(task, inst), a) in &finals {
            if task != t {
                continue;
            }
            n += 1;
            total += a.duration;
            cpu += a.cpu_secs;
            rss += a.max_rss_kb;
            if on_path.contains(&(task, inst)) {
                crit += 1;
            }
        }
        let id = dag.name(t);
        let (mut slack_sum, mut slack_n) = (0.0f64, 0usize);
        for inst in &instances {
            if let Some(s) = inst.slack.get(id) {
                slack_sum += s;
                slack_n += 1;
            }
        }
        let denom = n.max(1) as f64;
        tasks.push(TaskDiagnosis {
            task_id: id.to_string(),
            n,
            total_secs: total,
            mean_secs: total / denom,
            on_critical: crit,
            mean_slack: slack_sum / slack_n.max(1) as f64,
            mean_cpu_secs: cpu / denom,
            mean_rss_kb: rss / denom,
        });
    }
    tasks.sort_by(|a, b| a.task_id.cmp(&b.task_id));

    // --- what-if replay ----------------------------------------------
    let durs: BTreeMap<(usize, u64), f64> =
        finals.iter().map(|(&k, a)| (k, a.duration)).collect();
    let baseline = replay(&dispatch_order, &durs, dag, workers, None);
    let mut what_if: Vec<WhatIf> = Vec::new();
    for t in 0..dag.len() {
        let scaled = replay(&dispatch_order, &durs, dag, workers, Some(t));
        let speedup_pct = if baseline > 0.0 {
            (baseline - scaled) / baseline * 100.0
        } else {
            0.0
        };
        what_if.push(WhatIf {
            task_id: dag.name(t).to_string(),
            baseline,
            scaled,
            speedup_pct,
        });
    }
    what_if.sort_by(|a, b| {
        b.speedup_pct
            .total_cmp(&a.speedup_pct)
            .then_with(|| a.task_id.cmp(&b.task_id))
    });

    Diagnosis {
        run,
        study,
        workers,
        makespan,
        attribution,
        instances,
        tasks,
        what_if,
        warnings: Vec::new(),
    }
}

/// Longest-path (forward + backward) analysis of one instance.
fn diagnose_instance(
    dag: &Dag,
    topo: &[usize],
    finals: &BTreeMap<(usize, u64), &Attempt>,
    inst: u64,
    attempts: &[Attempt],
) -> InstanceDiagnosis {
    let n = dag.len();
    let dur: Vec<f64> = (0..n)
        .map(|t| finals.get(&(t, inst)).map_or(0.0, |a| a.duration))
        .collect();
    // forward: longest path ending at i (inclusive of i)
    let mut fwd = vec![0.0f64; n];
    for &t in topo {
        let best = dag
            .dependencies(t)
            .iter()
            .map(|&d| fwd[d])
            .fold(0.0f64, f64::max);
        fwd[t] = dur[t] + best;
    }
    // backward: longest path starting at i (inclusive of i)
    let mut bwd = vec![0.0f64; n];
    for &t in topo.iter().rev() {
        let best = dag
            .dependents(t)
            .iter()
            .map(|&d| bwd[d])
            .fold(0.0f64, f64::max);
        bwd[t] = dur[t] + best;
    }
    let critical_len = fwd.iter().copied().fold(0.0f64, f64::max);
    // backtrack from the sink with the longest finishing path
    // (smallest index wins ties, so the path is deterministic)
    let mut path_rev: Vec<usize> = Vec::new();
    let mut cur = (0..n).fold(0usize, |best, t| {
        if fwd[t] > fwd[best] {
            t
        } else {
            best
        }
    });
    if n > 0 {
        loop {
            path_rev.push(cur);
            let mut next: Option<usize> = None;
            for &d in dag.dependencies(cur) {
                if next.map_or(true, |b| fwd[d] > fwd[b]) {
                    next = Some(d);
                }
            }
            match next {
                Some(d) => cur = d,
                None => break,
            }
        }
    }
    let critical_path: Vec<String> = path_rev
        .iter()
        .rev()
        .map(|&t| dag.name(t).to_string())
        .collect();
    let slack: BTreeMap<String, f64> = (0..n)
        .map(|t| {
            let s = critical_len - (fwd[t] + bwd[t] - dur[t]);
            let s = if s < 1e-9 { 0.0 } else { s };
            (dag.name(t).to_string(), s)
        })
        .collect();
    let (mut lo, mut hi) = (f64::INFINITY, 0.0f64);
    for a in attempts.iter().filter(|a| a.instance == inst) {
        lo = lo.min(a.start);
        hi = hi.max(a.end);
    }
    InstanceDiagnosis {
        instance: inst,
        span: if lo.is_finite() { hi - lo } else { 0.0 },
        critical_len,
        critical_path,
        slack,
    }
}

/// Worker-seconds idle while dispatched work waited in the ready queue:
/// ∫ min(idle_workers(t), ready_depth(t)) dt over [0, makespan], swept
/// over the journal's dispatch/start/end breakpoints.
fn starvation_secs(
    attempts: &[Attempt],
    dispatch_ts: &[f64],
    workers: usize,
    makespan: f64,
) -> f64 {
    // (time, Δready, Δbusy) deltas
    let mut deltas: Vec<(f64, i64, i64)> = Vec::new();
    for &ts in dispatch_ts {
        deltas.push((ts, 1, 0));
    }
    for a in attempts {
        deltas.push((a.start, -1, 1));
        deltas.push((a.end, 0, -1));
    }
    deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
    let (mut ready, mut busy) = (0i64, 0i64);
    let mut prev = 0.0f64;
    let mut starved = 0.0f64;
    for &(t, dr, db) in &deltas {
        let t = t.min(makespan);
        if t > prev {
            let idle = (workers as i64 - busy).max(0);
            starved += ready.min(idle).max(0) as f64 * (t - prev);
            prev = t;
        }
        ready += dr;
        busy += db;
    }
    starved
}

/// Greedy list-schedule replay: dispatch `order` onto `workers` lanes,
/// each task to the earliest-free lane, constrained by its DAG
/// dependencies within the same instance. Halves the durations of
/// `scale_task` when set. Returns the virtual makespan.
fn replay(
    order: &[(usize, u64)],
    durs: &BTreeMap<(usize, u64), f64>,
    dag: &Dag,
    workers: usize,
    scale_task: Option<usize>,
) -> f64 {
    let mut free = vec![0.0f64; workers.max(1)];
    let mut finish: BTreeMap<(usize, u64), f64> = BTreeMap::new();
    for &(t, inst) in order {
        let mut dur = durs.get(&(t, inst)).copied().unwrap_or(0.0);
        if scale_task == Some(t) {
            dur *= 0.5;
        }
        let ready = dag
            .dependencies(t)
            .iter()
            .map(|&d| finish.get(&(d, inst)).copied().unwrap_or(0.0))
            .fold(0.0f64, f64::max);
        let lane = (0..free.len())
            .min_by(|&a, &b| free[a].total_cmp(&free[b]))
            .unwrap_or(0);
        let start = free[lane].max(ready);
        free[lane] = start + dur;
        finish.insert((t, inst), start + dur);
    }
    free.into_iter().fold(0.0, f64::max)
}

/// Memory-budget check: the worst case for a full window is every lane
/// running the hungriest task, so predict `workers × max(mean RSS)`
/// from the fitted [`CostModel`] and warn when it exceeds `budget_kb`.
/// Returns `None` when no task has sampled RSS evidence or the
/// prediction fits.
pub fn check_mem_budget(
    model: &CostModel,
    task_ids: &[String],
    workers: usize,
    budget_kb: f64,
) -> Option<String> {
    let mut worst: Option<(&str, f64)> = None;
    for id in task_ids {
        if let Some(kb) = model.rss_mean(id) {
            if worst.map_or(true, |(_, w)| kb > w) {
                worst = Some((id, kb));
            }
        }
    }
    let (id, kb) = worst?;
    let predicted = kb * workers as f64;
    if predicted <= budget_kb {
        return None;
    }
    Some(format!(
        "predicted window RSS {predicted:.0} KiB ({workers} workers x \
         {kb:.0} KiB mean for task '{id}') exceeds --mem-budget \
         {budget_kb:.0} KiB"
    ))
}

impl Diagnosis {
    /// Serialize the full report. Object keys sort, vectors are built
    /// in deterministic order, so the rendering is byte-stable across
    /// replays of the same journal.
    pub fn to_json(&self) -> Json {
        let a = &self.attribution;
        let attribution = Json::obj([
            ("critical_compute".to_string(), Json::Num(a.critical_compute)),
            ("idle".to_string(), Json::Num(a.idle)),
            ("other_compute".to_string(), Json::Num(a.other_compute)),
            ("retry_waste".to_string(), Json::Num(a.retry_waste)),
            (
                "scheduler_overhead".to_string(),
                Json::Num(a.scheduler_overhead),
            ),
            (
                "total_worker_secs".to_string(),
                Json::Num(a.total_worker_secs),
            ),
        ]);
        let instances = Json::Arr(
            self.instances
                .iter()
                .map(|i| {
                    Json::obj([
                        (
                            "critical_len".to_string(),
                            Json::Num(i.critical_len),
                        ),
                        (
                            "critical_path".to_string(),
                            Json::Arr(
                                i.critical_path
                                    .iter()
                                    .map(|s| Json::from(s.as_str()))
                                    .collect(),
                            ),
                        ),
                        (
                            "instance".to_string(),
                            Json::from(i.instance as i64),
                        ),
                        (
                            "slack".to_string(),
                            Json::obj(
                                i.slack
                                    .iter()
                                    .map(|(k, v)| (k.clone(), Json::Num(*v))),
                            ),
                        ),
                        ("span".to_string(), Json::Num(i.span)),
                    ])
                })
                .collect(),
        );
        let tasks = Json::Arr(
            self.tasks
                .iter()
                .map(|t| {
                    Json::obj([
                        (
                            "mean_cpu_secs".to_string(),
                            Json::Num(t.mean_cpu_secs),
                        ),
                        (
                            "mean_rss_kb".to_string(),
                            Json::Num(t.mean_rss_kb),
                        ),
                        ("mean_secs".to_string(), Json::Num(t.mean_secs)),
                        ("mean_slack".to_string(), Json::Num(t.mean_slack)),
                        ("n".to_string(), Json::from(t.n as i64)),
                        (
                            "on_critical".to_string(),
                            Json::from(t.on_critical as i64),
                        ),
                        (
                            "task_id".to_string(),
                            Json::from(t.task_id.as_str()),
                        ),
                        ("total_secs".to_string(), Json::Num(t.total_secs)),
                    ])
                })
                .collect(),
        );
        let what_if = Json::Arr(
            self.what_if
                .iter()
                .map(|w| {
                    Json::obj([
                        ("baseline".to_string(), Json::Num(w.baseline)),
                        ("scaled".to_string(), Json::Num(w.scaled)),
                        (
                            "speedup_pct".to_string(),
                            Json::Num(w.speedup_pct),
                        ),
                        (
                            "task_id".to_string(),
                            Json::from(w.task_id.as_str()),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj([
            ("attribution".to_string(), attribution),
            ("instances".to_string(), instances),
            ("makespan".to_string(), Json::Num(self.makespan)),
            ("run".to_string(), Json::from(self.run as i64)),
            ("study".to_string(), Json::from(self.study.as_str())),
            ("tasks".to_string(), tasks),
            (
                "warnings".to_string(),
                Json::Arr(
                    self.warnings
                        .iter()
                        .map(|w| Json::from(w.as_str()))
                        .collect(),
                ),
            ),
            ("what_if".to_string(), what_if),
            ("workers".to_string(), Json::from(self.workers as i64)),
        ])
    }

    /// Human-readable report (the default `papas doctor` output).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let a = &self.attribution;
        let pct = |x: f64| {
            if a.total_worker_secs > 0.0 {
                x / a.total_worker_secs * 100.0
            } else {
                0.0
            }
        };
        out.push_str(&format!(
            "papas doctor — study '{}' run {}\n",
            self.study, self.run
        ));
        out.push_str(&format!(
            "makespan {:.2} s on {} workers ({:.2} worker-seconds)\n\n",
            self.makespan, self.workers, a.total_worker_secs
        ));
        out.push_str("bottleneck attribution\n");
        for (label, secs) in [
            ("critical-path compute", a.critical_compute),
            ("off-critical compute", a.other_compute),
            ("retry/backoff waste", a.retry_waste),
            ("scheduler overhead", a.scheduler_overhead),
            ("worker idle", a.idle),
        ] {
            out.push_str(&format!(
                "  {label:<22} {secs:>9.2} s {:>6.1}%\n",
                pct(secs)
            ));
        }
        out.push('\n');
        const SHOW: usize = 8;
        for inst in self.instances.iter().take(SHOW) {
            out.push_str(&format!(
                "instance {}: span {:.2} s, critical path {:.2} s\n  {}\n",
                inst.instance,
                inst.span,
                inst.critical_len,
                inst.critical_path.join(" -> ")
            ));
            let slackers: Vec<String> = inst
                .slack
                .iter()
                .filter(|(_, s)| **s > 0.0)
                .map(|(id, s)| format!("{id} {s:.2} s"))
                .collect();
            if !slackers.is_empty() {
                out.push_str(&format!(
                    "  slack: {}\n",
                    slackers.join(", ")
                ));
            }
        }
        if self.instances.len() > SHOW {
            out.push_str(&format!(
                "  ... and {} more instances\n",
                self.instances.len() - SHOW
            ));
        }
        out.push('\n');
        out.push_str(
            "task            runs   total s    mean s  crit  \
             slack s    rss kb\n",
        );
        for t in &self.tasks {
            out.push_str(&format!(
                "{:<14} {:>5} {:>9.2} {:>9.2} {:>5} {:>8.2} {:>9.0}\n",
                t.task_id,
                t.n,
                t.total_secs,
                t.mean_secs,
                t.on_critical,
                t.mean_slack,
                t.mean_rss_kb
            ));
        }
        out.push('\n');
        out.push_str("what-if (task 2x faster => replayed makespan)\n");
        for w in &self.what_if {
            out.push_str(&format!(
                "  {:<14} {:>8.2} s -> {:>8.2} s  (-{:.1}%)\n",
                w.task_id, w.baseline, w.scaled, w.speedup_pct
            ));
        }
        for w in &self.warnings {
            out.push_str(&format!("\nwarning: {w}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TraceEvent;

    fn diamond() -> Dag {
        Dag::new(&[
            ("a".to_string(), vec![]),
            ("b".to_string(), vec!["a".to_string()]),
            ("c".to_string(), vec!["a".to_string()]),
            ("d".to_string(), vec!["b".to_string(), "c".to_string()]),
        ])
        .unwrap()
    }

    fn complete(
        task: &str,
        inst: u64,
        start: f64,
        end: f64,
        ok: bool,
        attempt: u32,
    ) -> Json {
        TraceEvent::Complete {
            key: format!("{task}#{inst}"),
            task_id: task.to_string(),
            instance: inst,
            worker: "w0".into(),
            attempt,
            ok,
            duration: end - start,
            start,
            end,
            class: None,
            cpu_secs: 0.0,
            max_rss_kb: 0,
            io_read_bytes: 0,
            io_write_bytes: 0,
        }
        .to_json(end)
    }

    fn dispatch(task: &str, inst: u64, ts: f64) -> Json {
        TraceEvent::Dispatch {
            key: format!("{task}#{inst}"),
            instance: inst,
        }
        .to_json(ts)
    }

    fn header(workers: usize) -> Json {
        TraceEvent::Header {
            run: 3,
            study: "diamond".into(),
            workers,
            n_instances: 1,
            epoch_unix: 0.0,
        }
        .to_json(0.0)
    }

    /// Diamond a(1s) -> {b(4s), c(2s)} -> d(1s) on 2 workers.
    /// Critical path a->b->d = 6s; c has 2s of slack.
    fn diamond_events() -> Vec<Json> {
        vec![
            header(2),
            dispatch("a", 0, 0.0),
            complete("a", 0, 0.0, 1.0, true, 1),
            dispatch("b", 0, 1.0),
            dispatch("c", 0, 1.0),
            complete("c", 0, 1.0, 3.0, true, 1),
            complete("b", 0, 1.0, 5.0, true, 1),
            dispatch("d", 0, 5.0),
            complete("d", 0, 5.0, 6.0, true, 1),
        ]
    }

    #[test]
    fn critical_path_and_slack_match_hand_computation() {
        let d = diagnose(&diamond_events(), &diamond());
        assert_eq!(d.run, 3);
        assert_eq!(d.study, "diamond");
        assert_eq!(d.workers, 2);
        assert_eq!(d.makespan, 6.0);
        assert_eq!(d.instances.len(), 1);
        let inst = &d.instances[0];
        assert_eq!(inst.critical_path, vec!["a", "b", "d"]);
        assert_eq!(inst.critical_len, 6.0);
        assert_eq!(inst.span, 6.0);
        assert_eq!(inst.slack["a"], 0.0);
        assert_eq!(inst.slack["b"], 0.0);
        assert_eq!(inst.slack["c"], 2.0);
        assert_eq!(inst.slack["d"], 0.0);
    }

    #[test]
    fn attribution_partitions_worker_seconds_exactly() {
        let d = diagnose(&diamond_events(), &diamond());
        let a = d.attribution;
        assert_eq!(a.total_worker_secs, 12.0);
        assert_eq!(a.critical_compute, 6.0); // a + b + d
        assert_eq!(a.other_compute, 2.0); // c
        assert_eq!(a.retry_waste, 0.0);
        assert_eq!(a.scheduler_overhead, 0.0);
        assert_eq!(a.idle, 4.0);
        let sum = a.critical_compute
            + a.other_compute
            + a.retry_waste
            + a.scheduler_overhead
            + a.idle;
        assert!((sum - a.total_worker_secs).abs() < 1e-9);
    }

    #[test]
    fn failed_attempts_and_backoff_count_as_waste() {
        // b fails once (1s burned), backs off 500ms, succeeds on
        // attempt 2 with the same 4s duration.
        let events = vec![
            header(2),
            dispatch("a", 0, 0.0),
            complete("a", 0, 0.0, 1.0, true, 1),
            dispatch("b", 0, 1.0),
            dispatch("c", 0, 1.0),
            complete("b", 0, 1.0, 2.0, false, 1),
            TraceEvent::Retry {
                key: "b#0".into(),
                attempt: 1,
                backoff_ms: 500,
                class: None,
            }
            .to_json(2.0),
            dispatch("b", 0, 2.5),
            complete("c", 0, 1.0, 3.0, true, 1),
            complete("b", 0, 2.5, 6.5, true, 2),
            dispatch("d", 0, 6.5),
            complete("d", 0, 6.5, 7.5, true, 1),
        ];
        let d = diagnose(&events, &diamond());
        // 1.0s failed attempt + 0.5s backoff
        assert_eq!(d.attribution.retry_waste, 1.5);
        // the final (attempt 2) duration drives the critical path:
        // a(1) + b(4) + d(1)
        assert_eq!(d.instances[0].critical_path, vec!["a", "b", "d"]);
        assert_eq!(d.attribution.critical_compute, 6.0);
    }

    #[test]
    fn starvation_is_idle_while_work_is_queued() {
        // 2 workers, but b and c sit dispatched for 2s before starting:
        // one waits on the only "active" lane pattern below.
        let events = vec![
            header(2),
            dispatch("a", 0, 0.0),
            complete("a", 0, 0.0, 1.0, true, 1),
            dispatch("b", 0, 1.0),
            dispatch("c", 0, 1.0),
            // both start 2s late: 2 idle workers, 2 queued tasks, 1..3
            complete("b", 0, 3.0, 7.0, true, 1),
            complete("c", 0, 3.0, 5.0, true, 1),
            dispatch("d", 0, 7.0),
            complete("d", 0, 7.0, 8.0, true, 1),
        ];
        let d = diagnose(&events, &diamond());
        // [1,3): min(idle=2, ready=2) = 2 → 4 worker-seconds starved
        assert_eq!(d.attribution.scheduler_overhead, 4.0);
    }

    #[test]
    fn what_if_replay_halves_the_right_task() {
        let d = diagnose(&diamond_events(), &diamond());
        // replay baseline equals the observed makespan on this journal
        let wb = d.what_if.iter().find(|w| w.task_id == "b").unwrap();
        assert_eq!(wb.baseline, 6.0);
        // b at 2s: a(1) -> b(2)||c(2) -> d(1) = 4s
        assert_eq!(wb.scaled, 4.0);
        assert!((wb.speedup_pct - 100.0 / 3.0).abs() < 1e-9);
        // halving c gains nothing: it is off the critical path
        let wc = d.what_if.iter().find(|w| w.task_id == "c").unwrap();
        assert_eq!(wc.scaled, 6.0);
        assert_eq!(wc.speedup_pct, 0.0);
        // rows sort best-first
        assert_eq!(d.what_if[0].task_id, "b");
    }

    #[test]
    fn json_rendering_is_byte_stable() {
        let events = diamond_events();
        let dag = diamond();
        let a = crate::json::to_string(&diagnose(&events, &dag).to_json());
        let b = crate::json::to_string(&diagnose(&events, &dag).to_json());
        assert_eq!(a, b);
        assert!(a.contains("\"critical_path\":[\"a\",\"b\",\"d\"]"));
    }

    #[test]
    fn empty_journal_degrades_gracefully() {
        let d = diagnose(&[], &diamond());
        assert_eq!(d.makespan, 0.0);
        assert_eq!(d.instances.len(), 0);
        assert_eq!(d.attribution.total_worker_secs, 0.0);
        assert_eq!(d.what_if[0].speedup_pct, 0.0);
        // text rendering stays panic-free
        assert!(d.render_text().contains("bottleneck attribution"));
    }
}
