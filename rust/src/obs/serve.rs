//! The metrics exporter: Prometheus text exposition + a tiny HTTP loop.
//!
//! `papas status --serve ADDR` binds a plain [`std::net::TcpListener`]
//! (no HTTP dependency — the request grammar we need is one line) and
//! answers two routes:
//!
//! * `GET /metrics` — the metrics registry rendered in Prometheus text
//!   exposition format (version 0.0.4), names sanitized to
//!   `[a-zA-Z0-9_:]` and prefixed `papas_`;
//! * `GET /status` — the same JSON summary `papas status --format json`
//!   prints.
//!
//! Both bodies are produced by closures evaluated per request, so a
//! scrape always sees the study database's current state. `once` mode
//! (the `--once` flag) accepts a single connection and returns — the
//! CI smoke test and anything else that wants a one-shot probe.

use super::metrics::Metrics;
use crate::util::error::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// Sanitize a registry name into a Prometheus metric name chunk:
/// every character outside `[a-zA-Z0-9_:]` becomes `_`.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Format a sample value the way Prometheus expects (`Display` for
/// finite floats; explicit spellings for the specials).
fn num(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{x}")
    }
}

/// Render the registry in Prometheus text exposition format. Counters
/// export as `counter`, gauges as `gauge`, and each histogram summary
/// as four series (`_count`, `_sum`, `_min`, `_max`). Deterministic:
/// the registry snapshot iterates sorted names.
pub fn render_prometheus(metrics: &Metrics) -> String {
    let snap = metrics.snapshot();
    let mut out = String::new();
    let mut push = |name: &str, kind: &str, help: &str, value: &str| {
        out.push_str(&format!("# HELP {name} {help}\n"));
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        out.push_str(&format!("{name} {value}\n"));
    };
    if let Some(counters) = snap.get("counters").and_then(|c| c.as_obj()) {
        for (k, v) in counters {
            let name = format!("papas_{}", sanitize(k));
            let value = v.as_i64().unwrap_or(0);
            push(&name, "counter", "Event counter from the run trace.", &value.to_string());
        }
    }
    if let Some(gauges) = snap.get("gauges").and_then(|g| g.as_obj()) {
        for (k, v) in gauges {
            let name = format!("papas_{}", sanitize(k));
            let value = v.as_f64().unwrap_or(0.0);
            push(&name, "gauge", "Latest value from the run trace.", &num(value));
        }
    }
    if let Some(hists) = snap.get("histograms").and_then(|h| h.as_obj()) {
        for (k, h) in hists {
            let base = format!("papas_{}", sanitize(k));
            let field = |key: &str| {
                h.get(key).and_then(crate::json::Json::as_f64).unwrap_or(0.0)
            };
            push(
                &format!("{base}_count"),
                "counter",
                "Observations folded from the run trace.",
                &num(field("n")),
            );
            for key in ["sum", "min", "max"] {
                push(
                    &format!("{base}_{key}"),
                    "gauge",
                    "Histogram summary from the run trace.",
                    &num(field(key)),
                );
            }
        }
    }
    out
}

/// Route one request path to `(content_type, body)`, or `None` → 404.
fn route(
    path: &str,
    metrics: &dyn Fn() -> String,
    status: &dyn Fn() -> String,
) -> Option<(&'static str, String)> {
    match path {
        "/metrics" => {
            Some(("text/plain; version=0.0.4; charset=utf-8", metrics()))
        }
        "/status" => Some(("application/json; charset=utf-8", status())),
        _ => None,
    }
}

fn handle(
    stream: TcpStream,
    metrics: &dyn Fn() -> String,
    status: &dyn Fn() -> String,
) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let mut reader = BufReader::new(stream);
    let mut request_line = String::new();
    if reader.read_line(&mut request_line).is_err() {
        return;
    }
    // "GET /metrics HTTP/1.1" → "/metrics"
    let path = request_line.split_whitespace().nth(1).unwrap_or("/");
    let (code, content_type, body) = match route(path, metrics, status) {
        Some((ct, body)) => ("200 OK", ct, body),
        None => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    let mut stream = reader.into_inner();
    let _ = write!(
        stream,
        "HTTP/1.1 {code}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.flush();
}

/// Accept-and-respond loop over an already-bound listener (the caller
/// binds so it can print the resolved address — `--serve 127.0.0.1:0`
/// picks an ephemeral port). `once` handles a single connection and
/// returns; otherwise the loop runs until the process dies.
pub fn serve(
    listener: TcpListener,
    once: bool,
    metrics: &dyn Fn() -> String,
    status: &dyn Fn() -> String,
) -> Result<()> {
    for stream in listener.incoming() {
        if let Ok(stream) = stream {
            handle(stream, metrics, status);
        }
        if once {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn registry() -> Metrics {
        let m = Metrics::new();
        m.add("tasks_ok", 5);
        m.inc("class.user-error");
        m.set_gauge("window_size", 8.0);
        m.observe("worker_busy_s.local-0", 1.5);
        m.observe("worker_busy_s.local-0", 2.5);
        m
    }

    #[test]
    fn exposition_is_valid_and_sanitized() {
        let text = render_prometheus(&registry());
        assert!(text.contains("# TYPE papas_tasks_ok counter\n"));
        assert!(text.contains("papas_tasks_ok 5\n"));
        assert!(text.contains("papas_class_user_error 1\n"));
        assert!(text.contains("# TYPE papas_window_size gauge\n"));
        assert!(text.contains("papas_window_size 8\n"));
        assert!(text.contains("papas_worker_busy_s_local_0_count 2\n"));
        assert!(text.contains("papas_worker_busy_s_local_0_sum 4\n"));
        assert!(text.contains("papas_worker_busy_s_local_0_min 1.5\n"));
        assert!(text.contains("papas_worker_busy_s_local_0_max 2.5\n"));
        // exposition grammar: every line is a comment or `name value`,
        // names restricted to [a-zA-Z0-9_:]
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.split_once(' ').unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric()
                    || c == '_'
                    || c == ':'),
                "bad metric name {name:?}"
            );
            assert!(value.parse::<f64>().is_ok(), "bad value {value:?}");
        }
        // deterministic
        assert_eq!(text, render_prometheus(&registry()));
    }

    #[test]
    fn empty_registry_renders_empty() {
        assert_eq!(render_prometheus(&Metrics::new()), "");
    }

    #[test]
    fn routes_metrics_status_and_404() {
        let metrics = || "papas_tasks_ok 1\n".to_string();
        let status = || "{\"state\":\"done\"}".to_string();
        let (ct, body) = route("/metrics", &metrics, &status).unwrap();
        assert!(ct.starts_with("text/plain"));
        assert_eq!(body, "papas_tasks_ok 1\n");
        let (ct, body) = route("/status", &metrics, &status).unwrap();
        assert!(ct.starts_with("application/json"));
        assert_eq!(body, "{\"state\":\"done\"}");
        assert!(route("/ghost", &metrics, &status).is_none());
    }

    #[test]
    fn once_mode_serves_one_http_request() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            serve(
                listener,
                true,
                &|| "papas_tasks_ok 3\n".to_string(),
                &|| "{}".to_string(),
            )
            .unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(response.contains("text/plain; version=0.0.4"));
        assert!(response.ends_with("papas_tasks_ok 3\n"));
        server.join().unwrap();
    }
}
