//! Run observability: structured event tracing + a metrics registry.
//!
//! PaPaS §4.2 stops at a task profiler that "only serves as performance
//! feedback to the user". This module is the event-level substrate
//! underneath it: every scheduler decision the elastic engine makes
//! (LPT pool picks, timeout inference, window resizes), every task
//! lifecycle edge (dispatch / complete / retry / timeout-kill), and
//! every durability action (checkpoint commit, harvest) can be appended
//! live to a per-run `trace-<run>.jsonl` journal and folded into a
//! counters/gauges/histograms registry snapshotted into `report.json`.
//!
//! Design constraints:
//!
//! - **Off by default, zero-cost when off.** The scheduler holds an
//!   `Option<Arc<TraceSink>>`; the disabled path is a single `Option`
//!   check per site, and dispatch order is bit-identical to the
//!   untraced engine.
//! - **Crash-tolerant like `attempts.jsonl`.** One JSON object per
//!   line, buffered writes, torn trailing lines skipped on read.
//! - **Replayable.** Timestamps come from a [`Clock`] — the real
//!   [`MonotonicClock`] on live runs, a [`ScriptedClock`] advanced by
//!   simulated task durations under `ScriptedExecutor`, so hermetic
//!   replays produce byte-identical journals.
//!
//! Inspection lives in `papas trace` (Chrome/Perfetto JSON, CSV, or an
//! ASCII summary via [`export`]) and `papas watch` (a live tail over
//! the journal via [`watch`]).

pub mod clock;
pub mod critical;
pub mod event;
pub mod export;
pub mod journal;
pub mod metrics;
pub mod serve;
pub mod telemetry;
pub mod watch;

pub use clock::{Clock, MonotonicClock, ScriptedClock};
pub use critical::{diagnose, Diagnosis};
pub use event::TraceEvent;
pub use journal::{
    fold_trace, latest_trace_run, read_trace, trace_path, TraceSink,
    SEARCH_TRACE_FILE,
};
pub use metrics::{Hist, Metrics};
pub use serve::render_prometheus;
pub use telemetry::{ResourceSampler, ResourceUsage};
pub use watch::WatchState;
