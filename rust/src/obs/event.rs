//! The typed trace-event vocabulary.
//!
//! One variant per observable edge: task lifecycle (dispatch /
//! complete / retry / timeout-kill), elastic-scheduler decisions (LPT
//! pool pick, timeout inference, window grow/resize), durability
//! actions (checkpoint commit, harvest), and search-round progress.
//! Events serialize to one JSON object per journal line; the sink
//! stamps the `ts` field, so serialization here is timestamp-free.
//!
//! Reading back is deliberately *untyped* (generic [`crate::json::Json`]
//! via [`super::read_trace`]): exporters and the watch view tolerate
//! unknown event kinds, so old tools read new journals.

use crate::exec::ErrorClass;
use crate::json::Json;

/// One structured trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Journal header: the first line of every trace file.
    Header {
        /// Provenance run id the journal belongs to.
        run: u32,
        /// Study name.
        study: String,
        /// Executor worker count.
        workers: usize,
        /// Instances selected for this run.
        n_instances: u64,
        /// Wall-clock UNIX seconds of the trace epoch (0.0 scripted).
        epoch_unix: f64,
    },
    /// A task instance was handed to the executor's ready queue.
    Dispatch {
        /// `task_id#instance` key.
        key: String,
        /// Workflow instance index.
        instance: u64,
    },
    /// The LPT packer chose a task out of the ready pool.
    LptPick {
        /// `task_id#instance` key.
        key: String,
        /// Predicted cost in seconds (None when the model had no
        /// evidence and admission order decided).
        predicted: Option<f64>,
        /// Pool depth at decision time (before removal).
        pool_depth: usize,
    },
    /// A task attempt finished (terminal or about to retry).
    Complete {
        /// `task_id#instance` key.
        key: String,
        /// Task id.
        task_id: String,
        /// Workflow instance index.
        instance: u64,
        /// Worker label that executed the attempt.
        worker: String,
        /// 1-based attempt number.
        attempt: u32,
        /// Whether the attempt succeeded.
        ok: bool,
        /// Attempt wall time in seconds.
        duration: f64,
        /// Start offset from the trace epoch (seconds).
        start: f64,
        /// End offset from the trace epoch (seconds).
        end: f64,
        /// Failure class (None on success).
        class: Option<ErrorClass>,
        /// Sampled user+system CPU seconds (0 when unsampled — see
        /// `obs::telemetry`).
        cpu_secs: f64,
        /// Sampled peak resident set in KiB (0 when unsampled).
        max_rss_kb: u64,
        /// Sampled storage-layer bytes read (0 when unsampled).
        io_read_bytes: u64,
        /// Sampled storage-layer bytes written (0 when unsampled).
        io_write_bytes: u64,
    },
    /// A failed attempt will be re-dispatched.
    Retry {
        /// `task_id#instance` key.
        key: String,
        /// The attempt number that just failed.
        attempt: u32,
        /// Backoff applied before the re-dispatch (milliseconds).
        backoff_ms: u64,
        /// Failure class of the failed attempt.
        class: Option<ErrorClass>,
    },
    /// A task died at its wall-clock limit (kill + reap).
    TimeoutKill {
        /// `task_id#instance` key.
        key: String,
        /// The limit it hit (seconds).
        limit: f64,
    },
    /// The scheduler filled in a missing timeout from the cost model.
    InferTimeout {
        /// `task_id#instance` key.
        key: String,
        /// Inferred limit (p95 × factor, seconds).
        limit: f64,
        /// The per-task p95 the limit came from (seconds).
        p95: f64,
    },
    /// The dynamic window doubled because admission stalled.
    WindowGrow {
        /// Window size before.
        from: usize,
        /// Window size after.
        to: usize,
    },
    /// The dynamic window was re-targeted from observed variance.
    WindowResize {
        /// Window size before.
        from: usize,
        /// Window size after.
        to: usize,
        /// Coefficient of variation of completed durations that
        /// triggered the resize.
        cov: f64,
    },
    /// The checkpoint was committed to disk.
    CheckpointCommit {
        /// Total keys (done + failed) in the committed checkpoint.
        keys: usize,
    },
    /// The result store snapshot was folded from the row log.
    Harvest {
        /// Live rows in the folded snapshot.
        rows: usize,
    },
    /// The run finished; the journal is complete.
    RunEnd,
    /// A search round proposed combinations.
    SearchPropose {
        /// 1-based round number.
        round: u32,
        /// Proposals in the round.
        n: usize,
    },
    /// A search round was scored against the result store.
    SearchScore {
        /// 1-based round number.
        round: u32,
        /// Proposals that produced a scoreable metric.
        scored: usize,
        /// Best score in the round, if any.
        best: Option<f64>,
    },
}

impl TraceEvent {
    /// The event kind label (the `ev` field of the journal line).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Header { .. } => "header",
            TraceEvent::Dispatch { .. } => "dispatch",
            TraceEvent::LptPick { .. } => "lpt_pick",
            TraceEvent::Complete { .. } => "complete",
            TraceEvent::Retry { .. } => "retry",
            TraceEvent::TimeoutKill { .. } => "timeout_kill",
            TraceEvent::InferTimeout { .. } => "infer_timeout",
            TraceEvent::WindowGrow { .. } => "window_grow",
            TraceEvent::WindowResize { .. } => "window_resize",
            TraceEvent::CheckpointCommit { .. } => "checkpoint_commit",
            TraceEvent::Harvest { .. } => "harvest",
            TraceEvent::RunEnd => "run_end",
            TraceEvent::SearchPropose { .. } => "search_propose",
            TraceEvent::SearchScore { .. } => "search_score",
        }
    }

    /// Serialize to one journal object; `ts` is stamped by the sink.
    /// The writer sorts object keys, so identical event sequences with
    /// identical timestamps serialize byte-identically.
    pub fn to_json(&self, ts: f64) -> Json {
        let mut fields: Vec<(String, Json)> = vec![
            ("ts".to_string(), Json::Num(ts)),
            ("ev".to_string(), Json::from(self.name())),
        ];
        let class_json = |c: &Option<ErrorClass>| {
            c.map(|c| Json::from(c.label())).unwrap_or(Json::Null)
        };
        match self {
            TraceEvent::Header { run, study, workers, n_instances, epoch_unix } => {
                fields.push(("run".to_string(), Json::from(*run as i64)));
                fields.push(("study".to_string(), Json::from(study.as_str())));
                fields.push(("workers".to_string(), Json::from(*workers as i64)));
                fields.push((
                    "n_instances".to_string(),
                    Json::from(*n_instances as i64),
                ));
                fields.push(("epoch_unix".to_string(), Json::Num(*epoch_unix)));
                fields.push(("version".to_string(), Json::from(1i64)));
            }
            TraceEvent::Dispatch { key, instance } => {
                fields.push(("key".to_string(), Json::from(key.as_str())));
                fields.push((
                    "instance".to_string(),
                    Json::from(*instance as i64),
                ));
            }
            TraceEvent::LptPick { key, predicted, pool_depth } => {
                fields.push(("key".to_string(), Json::from(key.as_str())));
                fields.push((
                    "predicted".to_string(),
                    predicted.map(Json::Num).unwrap_or(Json::Null),
                ));
                fields.push((
                    "pool_depth".to_string(),
                    Json::from(*pool_depth as i64),
                ));
            }
            TraceEvent::Complete {
                key,
                task_id,
                instance,
                worker,
                attempt,
                ok,
                duration,
                start,
                end,
                class,
                cpu_secs,
                max_rss_kb,
                io_read_bytes,
                io_write_bytes,
            } => {
                fields.push(("key".to_string(), Json::from(key.as_str())));
                fields.push((
                    "task_id".to_string(),
                    Json::from(task_id.as_str()),
                ));
                fields.push((
                    "instance".to_string(),
                    Json::from(*instance as i64),
                ));
                fields.push(("worker".to_string(), Json::from(worker.as_str())));
                fields.push(("attempt".to_string(), Json::from(*attempt as i64)));
                fields.push(("ok".to_string(), Json::from(*ok)));
                fields.push(("duration".to_string(), Json::Num(*duration)));
                fields.push(("start".to_string(), Json::Num(*start)));
                fields.push(("end".to_string(), Json::Num(*end)));
                fields.push(("class".to_string(), class_json(class)));
                fields.push(("cpu_secs".to_string(), Json::Num(*cpu_secs)));
                fields.push((
                    "max_rss_kb".to_string(),
                    Json::from(*max_rss_kb as i64),
                ));
                fields.push((
                    "io_read_bytes".to_string(),
                    Json::from(*io_read_bytes as i64),
                ));
                fields.push((
                    "io_write_bytes".to_string(),
                    Json::from(*io_write_bytes as i64),
                ));
            }
            TraceEvent::Retry { key, attempt, backoff_ms, class } => {
                fields.push(("key".to_string(), Json::from(key.as_str())));
                fields.push(("attempt".to_string(), Json::from(*attempt as i64)));
                fields.push((
                    "backoff_ms".to_string(),
                    Json::from(*backoff_ms as i64),
                ));
                fields.push(("class".to_string(), class_json(class)));
            }
            TraceEvent::TimeoutKill { key, limit } => {
                fields.push(("key".to_string(), Json::from(key.as_str())));
                fields.push(("limit".to_string(), Json::Num(*limit)));
            }
            TraceEvent::InferTimeout { key, limit, p95 } => {
                fields.push(("key".to_string(), Json::from(key.as_str())));
                fields.push(("limit".to_string(), Json::Num(*limit)));
                fields.push(("p95".to_string(), Json::Num(*p95)));
            }
            TraceEvent::WindowGrow { from, to } => {
                fields.push(("from".to_string(), Json::from(*from as i64)));
                fields.push(("to".to_string(), Json::from(*to as i64)));
            }
            TraceEvent::WindowResize { from, to, cov } => {
                fields.push(("from".to_string(), Json::from(*from as i64)));
                fields.push(("to".to_string(), Json::from(*to as i64)));
                fields.push(("cov".to_string(), Json::Num(*cov)));
            }
            TraceEvent::CheckpointCommit { keys } => {
                fields.push(("keys".to_string(), Json::from(*keys as i64)));
            }
            TraceEvent::Harvest { rows } => {
                fields.push(("rows".to_string(), Json::from(*rows as i64)));
            }
            TraceEvent::RunEnd => {}
            TraceEvent::SearchPropose { round, n } => {
                fields.push(("round".to_string(), Json::from(*round as i64)));
                fields.push(("n".to_string(), Json::from(*n as i64)));
            }
            TraceEvent::SearchScore { round, scored, best } => {
                fields.push(("round".to_string(), Json::from(*round as i64)));
                fields.push(("scored".to_string(), Json::from(*scored as i64)));
                fields.push((
                    "best".to_string(),
                    best.map(Json::Num).unwrap_or(Json::Null),
                ));
            }
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn events_round_trip_through_the_writer() {
        let ev = TraceEvent::Dispatch { key: "t#3".into(), instance: 3 };
        let line = json::to_string(&ev.to_json(1.25));
        let j = json::parse(&line).unwrap();
        assert_eq!(j.expect_str("ev").unwrap(), "dispatch");
        assert_eq!(j.expect_str("key").unwrap(), "t#3");
        assert_eq!(j.expect_i64("instance").unwrap(), 3);
        assert_eq!(j.expect("ts").unwrap().as_f64(), Some(1.25));
        // serialization is deterministic (sorted keys)
        assert_eq!(line, json::to_string(&ev.to_json(1.25)));
    }

    #[test]
    fn optional_fields_serialize_as_null() {
        let ev = TraceEvent::LptPick {
            key: "t#0".into(),
            predicted: None,
            pool_depth: 4,
        };
        let j = ev.to_json(0.0);
        assert_eq!(j.get("predicted"), Some(&Json::Null));
        assert_eq!(j.expect_i64("pool_depth").unwrap(), 4);
        let ev = TraceEvent::Complete {
            key: "t#0".into(),
            task_id: "t".into(),
            instance: 0,
            worker: "local-0".into(),
            attempt: 1,
            ok: true,
            duration: 0.5,
            start: 1.0,
            end: 1.5,
            class: None,
            cpu_secs: 0.25,
            max_rss_kb: 1024,
            io_read_bytes: 10,
            io_write_bytes: 20,
        };
        let j = ev.to_json(1.5);
        assert_eq!(j.get("class"), Some(&Json::Null));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("cpu_secs").and_then(Json::as_f64), Some(0.25));
        assert_eq!(j.get("max_rss_kb").and_then(Json::as_i64), Some(1024));
    }

    #[test]
    fn every_variant_has_a_distinct_name() {
        let names = [
            TraceEvent::RunEnd.name(),
            TraceEvent::Harvest { rows: 0 }.name(),
            TraceEvent::CheckpointCommit { keys: 0 }.name(),
            TraceEvent::WindowGrow { from: 1, to: 2 }.name(),
            TraceEvent::WindowResize { from: 2, to: 3, cov: 0.1 }.name(),
            TraceEvent::SearchPropose { round: 1, n: 2 }.name(),
            TraceEvent::SearchScore { round: 1, scored: 2, best: None }.name(),
        ];
        let set: std::collections::BTreeSet<&str> =
            names.iter().copied().collect();
        assert_eq!(set.len(), names.len());
    }
}
