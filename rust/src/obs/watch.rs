//! Live run monitoring: fold a (possibly still-growing) journal into a
//! compact progress view for `papas watch`.
//!
//! The watcher re-reads the journal tolerantly (torn trailing lines are
//! skipped) and folds every event into a [`WatchState`]; rendering is a
//! single status line plus a short decision summary, cheap enough to
//! refresh every second on large journals.

use crate::json::Json;

/// Accumulated view of a run, folded from journal events in order.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct WatchState {
    /// Run id from the header (0 before one is seen).
    pub run: u32,
    /// Study name from the header.
    pub study: String,
    /// Worker count from the header.
    pub workers: usize,
    /// Total instances the run will execute, from the header.
    pub n_instances: u64,
    /// Tasks dispatched so far.
    pub dispatched: u64,
    /// Attempts that completed successfully.
    pub ok: u64,
    /// Attempts that completed in failure (including ones retried).
    pub failed: u64,
    /// Retries scheduled.
    pub retries: u64,
    /// Timeout kills.
    pub timeout_kills: u64,
    /// Latest admission window size (0 = unwindowed).
    pub window: usize,
    /// Latest LPT pool depth.
    pub pool_depth: usize,
    /// Sum of completed attempt durations.
    dur_sum: f64,
    /// Completed attempt count (denominator for the mean duration).
    dur_n: u64,
    /// Timestamp of the most recent event.
    pub last_ts: f64,
    /// True once a `run_end` event was seen.
    pub ended: bool,
}

impl WatchState {
    /// Fold one parsed journal event into the state.
    pub fn ingest(&mut self, ev: &Json) {
        if let Some(ts) = ev.get("ts").and_then(Json::as_f64) {
            self.last_ts = self.last_ts.max(ts);
        }
        let int = |key: &str| ev.get(key).and_then(Json::as_i64).unwrap_or(0);
        match ev.get("ev").and_then(Json::as_str).unwrap_or("") {
            "header" => {
                self.run = int("run") as u32;
                self.study = ev
                    .get("study")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                self.workers = int("workers") as usize;
                self.n_instances = int("n_instances") as u64;
            }
            "dispatch" => self.dispatched += 1,
            "lpt_pick" => self.pool_depth = int("pool_depth") as usize,
            "complete" => {
                if ev.get("ok").and_then(Json::as_bool).unwrap_or(false) {
                    self.ok += 1;
                } else {
                    self.failed += 1;
                }
                if let Some(d) = ev.get("duration").and_then(Json::as_f64) {
                    self.dur_sum += d;
                    self.dur_n += 1;
                }
            }
            "retry" => self.retries += 1,
            "timeout_kill" => self.timeout_kills += 1,
            "window_grow" | "window_resize" => {
                self.window = int("to") as usize;
            }
            "run_end" => self.ended = true,
            _ => {}
        }
    }

    /// Completed attempts (ok + failed).
    pub fn completed(&self) -> u64 {
        self.ok + self.failed
    }

    /// Dispatched but not yet completed.
    pub fn in_flight(&self) -> u64 {
        self.dispatched.saturating_sub(self.completed())
    }

    /// Mean completed-attempt duration in seconds (0.0 before any).
    pub fn mean_duration(&self) -> f64 {
        if self.dur_n == 0 {
            0.0
        } else {
            self.dur_sum / self.dur_n as f64
        }
    }

    /// Naive remaining-time estimate: outstanding instances at the mean
    /// duration spread across the workers. 0.0 once ended.
    pub fn eta_s(&self) -> f64 {
        if self.ended || self.n_instances == 0 {
            return 0.0;
        }
        let remaining = self.n_instances.saturating_sub(self.ok) as f64;
        remaining * self.mean_duration() / self.workers.max(1) as f64
    }

    /// Render the state as a short status block.
    pub fn render(&self) -> String {
        let status = if self.ended { "done" } else { "running" };
        let mut line = format!(
            "[{:>8.1}s] {} run {} ({}): {}/{} ok, {} failed, {} in flight",
            self.last_ts,
            self.study,
            self.run,
            status,
            self.ok,
            self.n_instances,
            self.failed,
            self.in_flight(),
        );
        if self.retries > 0 {
            line.push_str(&format!(", {} retries", self.retries));
        }
        if self.timeout_kills > 0 {
            line.push_str(&format!(", {} timeouts", self.timeout_kills));
        }
        if self.window > 0 {
            line.push_str(&format!(", window {}", self.window));
        }
        if !self.ended && self.dur_n > 0 {
            line.push_str(&format!(", eta ~{:.0}s", self.eta_s()));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::super::event::TraceEvent;
    use super::*;

    fn fold(state: &mut WatchState, ts: f64, ev: TraceEvent) {
        state.ingest(&ev.to_json(ts));
    }

    #[test]
    fn state_folds_a_run_in_order() {
        let mut s = WatchState::default();
        fold(
            &mut s,
            0.0,
            TraceEvent::Header {
                run: 3,
                study: "sweep".into(),
                workers: 2,
                n_instances: 4,
                epoch_unix: 0.0,
            },
        );
        for i in 0..4u64 {
            fold(
                &mut s,
                0.0,
                TraceEvent::Dispatch { key: format!("t#{i}"), instance: i },
            );
        }
        fold(
            &mut s,
            2.0,
            TraceEvent::Complete {
                key: "t#0".into(),
                task_id: "t".into(),
                instance: 0,
                worker: "local-0".into(),
                attempt: 1,
                ok: true,
                duration: 2.0,
                start: 0.0,
                end: 2.0,
                class: None,
                cpu_secs: 0.0,
                max_rss_kb: 0,
                io_read_bytes: 0,
                io_write_bytes: 0,
            },
        );
        fold(
            &mut s,
            2.5,
            TraceEvent::Retry {
                key: "t#1".into(),
                attempt: 1,
                backoff_ms: 100,
                class: None,
            },
        );
        assert_eq!(s.run, 3);
        assert_eq!(s.study, "sweep");
        assert_eq!(s.dispatched, 4);
        assert_eq!(s.ok, 1);
        assert_eq!(s.in_flight(), 3);
        assert_eq!(s.retries, 1);
        assert_eq!(s.mean_duration(), 2.0);
        // 3 remaining × 2.0s mean / 2 workers
        assert_eq!(s.eta_s(), 3.0);
        assert!(!s.ended);
        let line = s.render();
        assert!(line.contains("sweep run 3 (running)"));
        assert!(line.contains("1/4 ok"));
        assert!(line.contains("1 retries"));
        fold(&mut s, 9.0, TraceEvent::RunEnd);
        assert!(s.ended);
        assert_eq!(s.eta_s(), 0.0);
        assert!(s.render().contains("(done)"));
        assert_eq!(s.last_ts, 9.0);
    }

    #[test]
    fn window_and_pool_depth_track_latest_values() {
        let mut s = WatchState::default();
        fold(&mut s, 0.1, TraceEvent::WindowGrow { from: 2, to: 4 });
        fold(
            &mut s,
            0.2,
            TraceEvent::WindowResize { from: 4, to: 8, cov: 0.4 },
        );
        fold(
            &mut s,
            0.3,
            TraceEvent::LptPick {
                key: "t#0".into(),
                predicted: Some(1.5),
                pool_depth: 7,
            },
        );
        assert_eq!(s.window, 8);
        assert_eq!(s.pool_depth, 7);
    }
}
