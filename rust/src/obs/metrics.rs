//! The metrics registry: named counters, gauges, and histograms.
//!
//! The trace sink folds every emitted event into this registry, so a
//! traced run ends with a ready-made quantitative summary — tasks by
//! exit class, retries, pool depth, window size, queue wait, per-worker
//! busy time — snapshotted into `report.json` (and therefore into
//! `papas status --format json`) without a second pass over the
//! journal.

use crate::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Streaming histogram summary: count / sum / min / max (mean derives).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hist {
    /// Observations.
    pub n: u64,
    /// Sum of observations.
    pub sum: f64,
    /// Smallest observation.
    pub min: f64,
    /// Largest observation.
    pub max: f64,
}

impl Hist {
    fn observe(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Mean observation (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    fn to_json(self) -> Json {
        Json::obj([
            ("n".to_string(), Json::from(self.n as i64)),
            ("sum".to_string(), Json::Num(self.sum)),
            ("mean".to_string(), Json::Num(self.mean())),
            ("min".to_string(), Json::Num(if self.n == 0 { 0.0 } else { self.min })),
            ("max".to_string(), Json::Num(if self.n == 0 { 0.0 } else { self.max })),
        ])
    }
}

impl Default for Hist {
    fn default() -> Self {
        Hist { n: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
}

/// Thread-safe registry of named counters, gauges, and histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    hists: Mutex<BTreeMap<String, Hist>>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a counter by 1.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Increment a counter by `n`.
    pub fn add(&self, name: &str, n: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += n;
    }

    /// Set a gauge to its latest value.
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    /// Record one histogram observation.
    pub fn observe(&self, name: &str, value: f64) {
        self.hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .observe(value);
    }

    /// A counter's current value (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// A gauge's current value.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// A histogram's current summary.
    pub fn hist(&self, name: &str) -> Option<Hist> {
        self.hists.lock().unwrap().get(name).copied()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.lock().unwrap().is_empty()
            && self.gauges.lock().unwrap().is_empty()
            && self.hists.lock().unwrap().is_empty()
    }

    /// Snapshot the whole registry as one JSON object (sorted names —
    /// the `report.json` / `papas status --format json` payload).
    pub fn snapshot(&self) -> Json {
        let counters = Json::obj(
            self.counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), Json::from(*v as i64))),
        );
        let gauges = Json::obj(
            self.gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v))),
        );
        let hists = Json::obj(
            self.hists
                .lock()
                .unwrap()
                .iter()
                .map(|(k, h)| (k.clone(), h.to_json())),
        );
        Json::obj([
            ("counters".to_string(), counters),
            ("gauges".to_string(), gauges),
            ("histograms".to_string(), hists),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms() {
        let m = Metrics::new();
        assert!(m.is_empty());
        m.inc("tasks_ok");
        m.inc("tasks_ok");
        m.add("tasks_ok", 3);
        m.set_gauge("window_size", 8.0);
        m.set_gauge("window_size", 12.0); // latest wins
        m.observe("queue_wait_s", 1.0);
        m.observe("queue_wait_s", 3.0);
        assert_eq!(m.counter("tasks_ok"), 5);
        assert_eq!(m.counter("ghost"), 0);
        assert_eq!(m.gauge("window_size"), Some(12.0));
        let h = m.hist("queue_wait_s").unwrap();
        assert_eq!(h.n, 2);
        assert_eq!(h.mean(), 2.0);
        assert_eq!((h.min, h.max), (1.0, 3.0));
        assert!(!m.is_empty());
    }

    #[test]
    fn snapshot_is_structured_and_deterministic() {
        let m = Metrics::new();
        m.inc("retries");
        m.set_gauge("pool_depth", 4.0);
        m.observe("task_duration_s", 2.5);
        let j = m.snapshot();
        assert_eq!(
            j.get("counters").unwrap().expect_i64("retries").unwrap(),
            1
        );
        assert_eq!(
            j.get("gauges")
                .unwrap()
                .get("pool_depth")
                .and_then(Json::as_f64),
            Some(4.0)
        );
        let h = j.get("histograms").unwrap().get("task_duration_s").unwrap();
        assert_eq!(h.expect_i64("n").unwrap(), 1);
        assert_eq!(h.get("mean").and_then(Json::as_f64), Some(2.5));
        // empty registry snapshots to three empty sections
        let e = Metrics::new().snapshot();
        assert_eq!(
            crate::json::to_string(&e),
            r#"{"counters":{},"gauges":{},"histograms":{}}"#
        );
    }
}
