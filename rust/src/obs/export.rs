//! Trace exporters: Chrome/Perfetto JSON, CSV, and an ASCII summary.
//!
//! All three operate on the parsed journal (`Vec<Json>` from
//! [`super::journal::read_trace`]) rather than on live [`TraceEvent`]s,
//! so they work on journals from crashed or foreign runs too.
//!
//! The Chrome export follows the trace-event format consumed by
//! `chrome://tracing` and [Perfetto](https://ui.perfetto.dev): one
//! thread track per worker carrying `B`/`E` duration spans (tasks on a
//! worker are sequential, so spans never overlap within a track), plus
//! a `tid 0` scheduler track of `i` instants for decision events (LPT
//! picks, window resizes, timeout inference, checkpoints), each keeping
//! its journal fields as `args`.

use crate::json::Json;
use crate::workflow::profiler::TaskRecord;
use std::collections::BTreeMap;

fn ev_name(e: &Json) -> &str {
    e.get("ev").and_then(Json::as_str).unwrap_or("")
}

fn micros(secs: f64) -> Json {
    Json::Num((secs * 1e6).round())
}

/// Journal fields that become structural Chrome fields, not `args`.
const STRUCTURAL: [&str; 2] = ["ts", "ev"];

fn args_of(e: &Json) -> Json {
    let Some(m) = e.as_obj() else {
        return Json::obj([]);
    };
    Json::obj(
        m.iter()
            .filter(|(k, _)| !STRUCTURAL.contains(&k.as_str()))
            .map(|(k, v)| (k.clone(), v.clone())),
    )
}

/// Sorted worker names seen in `complete` events; track ids start at 1
/// (tid 0 is the scheduler's instant track).
fn worker_tids(events: &[Json]) -> BTreeMap<String, usize> {
    let mut tids = BTreeMap::new();
    for e in events {
        if ev_name(e) != "complete" {
            continue;
        }
        if let Some(w) = e.get("worker").and_then(Json::as_str) {
            let next = tids.len() + 1;
            tids.entry(w.to_string()).or_insert(next);
        }
    }
    tids
}

/// Convert a parsed journal into Chrome trace-event JSON
/// (`{"traceEvents": [...]}`), loadable in `chrome://tracing` and
/// Perfetto.
pub fn to_chrome(events: &[Json]) -> Json {
    let tids = worker_tids(events);
    let mut out: Vec<Json> = Vec::new();
    let meta = |tid: usize, name: &str| {
        Json::obj([
            ("name".to_string(), Json::from("thread_name")),
            ("ph".to_string(), Json::from("M")),
            ("pid".to_string(), Json::from(1usize)),
            ("tid".to_string(), Json::from(tid)),
            (
                "args".to_string(),
                Json::obj([("name".to_string(), Json::from(name))]),
            ),
        ])
    };
    out.push(meta(0, "scheduler"));
    for (worker, tid) in &tids {
        out.push(meta(*tid, worker));
    }
    let mut timed: Vec<(f64, Json)> = Vec::new();
    for e in events {
        let ts = e.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
        match ev_name(e) {
            "" => {}
            "complete" => {
                let worker = e.get("worker").and_then(Json::as_str).unwrap_or("");
                let tid = tids.get(worker).copied().unwrap_or(0);
                let key = e.get("key").and_then(Json::as_str).unwrap_or("task");
                let start = e.get("start").and_then(Json::as_f64).unwrap_or(ts);
                let end = e.get("end").and_then(Json::as_f64).unwrap_or(ts);
                let span = |ph: &str, at: f64| {
                    Json::obj([
                        ("name".to_string(), Json::from(key)),
                        ("cat".to_string(), Json::from("task")),
                        ("ph".to_string(), Json::from(ph)),
                        ("ts".to_string(), micros(at)),
                        ("pid".to_string(), Json::from(1usize)),
                        ("tid".to_string(), Json::from(tid)),
                        ("args".to_string(), args_of(e)),
                    ])
                };
                timed.push((start, span("B", start)));
                timed.push((end, span("E", end)));
            }
            name => {
                timed.push((
                    ts,
                    Json::obj([
                        ("name".to_string(), Json::from(name)),
                        ("cat".to_string(), Json::from("scheduler")),
                        ("ph".to_string(), Json::from("i")),
                        ("s".to_string(), Json::from("t")),
                        ("ts".to_string(), micros(ts)),
                        ("pid".to_string(), Json::from(1usize)),
                        ("tid".to_string(), Json::from(0usize)),
                        ("args".to_string(), args_of(e)),
                    ]),
                ));
            }
        }
    }
    // Stable sort keeps B before E for zero-duration spans.
    timed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    out.extend(timed.into_iter().map(|(_, e)| e));
    Json::obj([("traceEvents".to_string(), Json::Arr(out))])
}

/// Flatten a parsed journal to CSV: fixed columns for the common
/// fields, remaining fields packed into a `detail` column as
/// `key=value` pairs.
pub fn to_csv(events: &[Json]) -> String {
    const COMMON: [&str; 6] = ["ts", "ev", "key", "worker", "ok", "duration"];
    let mut out = String::from("ts,ev,key,worker,ok,duration,detail\n");
    for e in events {
        let Some(m) = e.as_obj() else { continue };
        let mut row: Vec<String> = COMMON
            .iter()
            .map(|k| {
                m.get(*k)
                    .map(|v| match v {
                        Json::Str(s) => crate::util::strings::csv_field(s),
                        other => crate::json::to_string(other),
                    })
                    .unwrap_or_default()
            })
            .collect();
        let detail = m
            .iter()
            .filter(|(k, _)| !COMMON.contains(&k.as_str()))
            .map(|(k, v)| format!("{k}={}", crate::json::to_string(v)))
            .collect::<Vec<_>>()
            .join(";");
        row.push(crate::util::strings::csv_field(&detail));
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Rebuild profiler-style task records from `complete` events (the
/// input to the ASCII Gantt renderer).
pub fn task_records(events: &[Json]) -> Vec<TaskRecord> {
    events
        .iter()
        .filter(|e| ev_name(e) == "complete")
        .map(|e| TaskRecord {
            key: e.get("key").and_then(Json::as_str).unwrap_or("").to_string(),
            task_id: e.get("task_id").and_then(Json::as_str).unwrap_or("").to_string(),
            instance: e.get("instance").and_then(Json::as_i64).unwrap_or(0) as u64,
            start: e.get("start").and_then(Json::as_f64).unwrap_or(0.0),
            end: e.get("end").and_then(Json::as_f64).unwrap_or(0.0),
            worker: e.get("worker").and_then(Json::as_str).unwrap_or("").to_string(),
            ok: e.get("ok").and_then(Json::as_bool).unwrap_or(false),
        })
        .collect()
}

/// Human summary of a journal: header line, event counts, per-worker
/// busy time, and an ASCII Gantt timeline.
pub fn render_summary(events: &[Json], cols: usize) -> String {
    let mut out = String::new();
    if let Some(h) = events.iter().find(|e| ev_name(e) == "header") {
        let study = h.get("study").and_then(Json::as_str).unwrap_or("?");
        let run = h.get("run").and_then(Json::as_i64).unwrap_or(0);
        let workers = h.get("workers").and_then(Json::as_i64).unwrap_or(0);
        let n = h.get("n_instances").and_then(Json::as_i64).unwrap_or(0);
        out.push_str(&format!(
            "study {study}  run {run}  workers {workers}  instances {n}\n"
        ));
    }
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    for e in events {
        let name = ev_name(e);
        if !name.is_empty() {
            *counts.entry(name).or_insert(0) += 1;
        }
    }
    out.push_str("events:");
    for (name, n) in &counts {
        out.push_str(&format!(" {name}={n}"));
    }
    out.push('\n');
    let records = task_records(events);
    if !records.is_empty() {
        let mut busy: BTreeMap<String, f64> = BTreeMap::new();
        for r in &records {
            *busy.entry(r.worker.clone()).or_insert(0.0) += r.duration();
        }
        let bars: Vec<(String, f64)> = busy.into_iter().collect();
        out.push_str("\nworker busy (s):\n");
        out.push_str(&crate::viz::render_bars(&bars, 40));
        out.push_str("\ntimeline:\n");
        out.push_str(&crate::viz::render_records(&records, cols));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::event::TraceEvent;
    use super::*;

    fn journal() -> Vec<Json> {
        let evs = [
            (
                0.0,
                TraceEvent::Header {
                    run: 0,
                    study: "demo".into(),
                    workers: 2,
                    n_instances: 2,
                    epoch_unix: 0.0,
                },
            ),
            (0.0, TraceEvent::Dispatch { key: "a#0".into(), instance: 0 }),
            (0.0, TraceEvent::Dispatch { key: "b#0".into(), instance: 0 }),
            (
                2.0,
                TraceEvent::Complete {
                    key: "a#0".into(),
                    task_id: "a".into(),
                    instance: 0,
                    worker: "local-0".into(),
                    attempt: 1,
                    ok: true,
                    duration: 2.0,
                    start: 0.0,
                    end: 2.0,
                    class: None,
                    cpu_secs: 0.0,
                    max_rss_kb: 0,
                    io_read_bytes: 0,
                    io_write_bytes: 0,
                },
            ),
            (
                3.0,
                TraceEvent::Complete {
                    key: "b#0".into(),
                    task_id: "b".into(),
                    instance: 0,
                    worker: "local-1".into(),
                    attempt: 1,
                    ok: true,
                    duration: 3.0,
                    start: 0.0,
                    end: 3.0,
                    class: None,
                    cpu_secs: 0.0,
                    max_rss_kb: 0,
                    io_read_bytes: 0,
                    io_write_bytes: 0,
                },
            ),
            (3.0, TraceEvent::RunEnd),
        ];
        evs.iter().map(|(ts, ev)| ev.to_json(*ts)).collect()
    }

    #[test]
    fn chrome_export_is_structurally_valid() {
        let chrome = to_chrome(&journal());
        let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 thread_name metadata (scheduler + 2 workers), 2 B/E pairs,
        // 4 instants (header, 2 dispatch, run_end)
        assert_eq!(events.len(), 3 + 4 + 4);
        let mut open = 0i64;
        for e in events {
            match e.expect_str("ph").unwrap() {
                "B" => open += 1,
                "E" => open -= 1,
                "i" => {
                    assert_eq!(e.expect_i64("tid").unwrap(), 0);
                    assert_eq!(e.expect_str("s").unwrap(), "t");
                }
                "M" => assert_eq!(e.expect_str("name").unwrap(), "thread_name"),
                other => panic!("unexpected phase {other}"),
            }
            assert!(open >= 0, "E before matching B");
        }
        assert_eq!(open, 0, "unbalanced B/E spans");
        // spans land on per-worker tracks with microsecond stamps
        let b = events
            .iter()
            .find(|e| e.expect_str("ph").unwrap() == "B")
            .unwrap();
        assert_eq!(b.expect_str("name").unwrap(), "a#0");
        assert!(b.expect_i64("tid").unwrap() >= 1);
        let e_span = events
            .iter()
            .find(|e| {
                e.expect_str("ph").unwrap() == "E"
                    && e.expect_str("name").unwrap() == "b#0"
            })
            .unwrap();
        assert_eq!(e_span.expect_i64("ts").unwrap(), 3_000_000);
    }

    #[test]
    fn csv_has_one_row_per_event() {
        let csv = to_csv(&journal());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + 6);
        assert_eq!(lines[0], "ts,ev,key,worker,ok,duration,detail");
        assert!(lines[4].starts_with("3,complete,b#0,local-1,true,3,"));
    }

    #[test]
    fn summary_counts_events_and_draws_workers() {
        let s = render_summary(&journal(), 60);
        assert!(s.contains("study demo  run 0  workers 2  instances 2"));
        assert!(s.contains("complete=2"));
        assert!(s.contains("dispatch=2"));
        assert!(s.contains("local-0"));
        assert!(s.contains("local-1"));
    }
}
