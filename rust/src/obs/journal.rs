//! The per-run trace journal: buffered append, torn-line-tolerant read.
//!
//! One file per run (`trace-<run>.jsonl`, next to `attempts.jsonl`),
//! one JSON object per line, first line a `header` event. Writes go
//! through a buffered writer behind a mutex and are **best-effort** —
//! a full disk degrades tracing, never the run. Reads skip torn
//! trailing lines exactly like the attempt log, so `papas watch` can
//! tail a journal that is still being written.
//!
//! The sink also folds every event into the [`Metrics`] registry as it
//! is emitted, so a traced run ends with counters/gauges/histograms
//! ready for `report.json` without a second pass over the journal.

use super::clock::Clock;
use super::event::TraceEvent;
use super::metrics::Metrics;
use crate::json::{self, Json};
use crate::util::error::Result;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Journal filename for search-driver events (round propose/score).
pub const SEARCH_TRACE_FILE: &str = "trace-search.jsonl";

/// Path of run `run`'s trace journal under a study database root.
pub fn trace_path(db_root: &Path, run: u32) -> PathBuf {
    db_root.join(format!("trace-{run}.jsonl"))
}

/// The highest run id with a trace journal under `db_root`, if any.
pub fn latest_trace_run(db_root: &Path) -> Option<u32> {
    let entries = std::fs::read_dir(db_root).ok()?;
    let mut latest: Option<u32> = None;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(mid) = name
            .strip_prefix("trace-")
            .and_then(|r| r.strip_suffix(".jsonl"))
        else {
            continue;
        };
        if let Ok(run) = mid.parse::<u32>() {
            latest = Some(latest.map_or(run, |l| l.max(run)));
        }
    }
    latest
}

/// Read a trace journal tolerantly: one event per parseable line, torn
/// or foreign lines skipped (the journal may still be appended to).
pub fn read_trace(path: &Path) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)?;
    let mut events = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(j) = json::parse(line) else { continue };
        if j.get("ev").and_then(Json::as_str).is_some() {
            events.push(j);
        }
    }
    Ok(events)
}

/// Rebuild a metrics registry offline from journal events read back via
/// [`read_trace`] — the `papas status --serve` `/metrics` endpoint's
/// per-scrape fold. Mirrors [`TraceSink::fold`] exactly (the round-trip
/// parity test below keeps the two in lockstep); unknown event kinds
/// are skipped so old binaries tolerate new journals.
pub fn fold_trace(events: &[Json]) -> Metrics {
    let m = Metrics::new();
    let mut dispatched: BTreeMap<String, f64> = BTreeMap::new();
    let f = |ev: &Json, key: &str| ev.get(key).and_then(Json::as_f64);
    for ev in events {
        let ts = f(ev, "ts").unwrap_or(0.0);
        match ev.get("ev").and_then(Json::as_str).unwrap_or("") {
            "header" => {
                if let Some(w) = f(ev, "workers") {
                    m.set_gauge("workers", w);
                }
            }
            "dispatch" => {
                m.inc("tasks_dispatched");
                if let Some(k) = ev.get("key").and_then(Json::as_str) {
                    dispatched.insert(k.to_string(), ts);
                }
            }
            "lpt_pick" => {
                m.inc("lpt_picks");
                if let Some(d) = f(ev, "pool_depth") {
                    m.set_gauge("pool_depth", d);
                }
            }
            "complete" => {
                let ok = ev
                    .get("ok")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                m.inc(if ok { "tasks_ok" } else { "tasks_failed" });
                if let Some(c) = ev.get("class").and_then(Json::as_str) {
                    m.inc(&format!("class.{c}"));
                }
                let duration = f(ev, "duration").unwrap_or(0.0);
                m.observe("task_duration_s", duration);
                let worker =
                    ev.get("worker").and_then(Json::as_str).unwrap_or("");
                m.observe(&format!("worker_busy_s.{worker}"), duration);
                let start = f(ev, "start").unwrap_or(0.0);
                let key = ev.get("key").and_then(Json::as_str).unwrap_or("");
                if let Some(d) = dispatched.remove(key) {
                    m.observe("queue_wait_s", (start - d).max(0.0));
                }
                let cpu = f(ev, "cpu_secs").unwrap_or(0.0);
                let rss = f(ev, "max_rss_kb").unwrap_or(0.0);
                let rd = f(ev, "io_read_bytes").unwrap_or(0.0);
                let wr = f(ev, "io_write_bytes").unwrap_or(0.0);
                if cpu != 0.0 || rss != 0.0 || rd != 0.0 || wr != 0.0 {
                    m.observe("task_cpu_s", cpu);
                    m.observe("task_rss_kb", rss);
                    m.add("io_read_bytes", rd as u64);
                    m.add("io_write_bytes", wr as u64);
                }
            }
            "retry" => m.inc("retries"),
            "timeout_kill" => m.inc("timeout_kills"),
            "infer_timeout" => m.inc("inferred_timeouts"),
            "window_grow" => {
                m.inc("window_grows");
                if let Some(to) = f(ev, "to") {
                    m.set_gauge("window_size", to);
                }
            }
            "window_resize" => {
                m.inc("window_resizes");
                if let Some(to) = f(ev, "to") {
                    m.set_gauge("window_size", to);
                }
            }
            "checkpoint_commit" => {
                m.inc("checkpoint_commits");
                if let Some(k) = f(ev, "keys") {
                    m.set_gauge("checkpoint_keys", k);
                }
            }
            "harvest" => {
                m.inc("harvests");
                if let Some(r) = f(ev, "rows") {
                    m.set_gauge("result_rows", r);
                }
            }
            "search_propose" => {
                m.add("search_proposed", f(ev, "n").unwrap_or(0.0) as u64);
            }
            "search_score" => {
                m.add(
                    "search_scored",
                    f(ev, "scored").unwrap_or(0.0) as u64,
                );
            }
            _ => {}
        }
    }
    m
}

/// The live event sink: stamps timestamps from its [`Clock`], appends
/// one line per event, and folds each event into the metrics registry.
pub struct TraceSink {
    writer: Mutex<BufWriter<File>>,
    clock: Arc<dyn Clock>,
    metrics: Metrics,
    /// Dispatch timestamps by key, consumed at completion to observe
    /// queue wait (time between admission and execution start).
    dispatched: Mutex<BTreeMap<String, f64>>,
}

impl TraceSink {
    /// Create (truncate) the journal at `path`.
    pub fn create(path: &Path, clock: Arc<dyn Clock>) -> Result<TraceSink> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = File::create(path)?;
        Ok(TraceSink {
            writer: Mutex::new(BufWriter::new(file)),
            clock,
            metrics: Metrics::new(),
            dispatched: Mutex::new(BTreeMap::new()),
        })
    }

    /// Seconds since the trace epoch (the sink's clock).
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Wall-clock UNIX seconds of the trace epoch (0.0 scripted).
    pub fn epoch_unix(&self) -> f64 {
        self.clock.epoch_unix()
    }

    /// The metrics registry this sink folds events into.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stamp, fold, and append one event. Best-effort: write errors are
    /// swallowed so tracing can never abort the run it observes.
    pub fn emit(&self, ev: &TraceEvent) {
        let ts = self.clock.now();
        self.fold(ev);
        let line = json::to_string(&ev.to_json(ts));
        let mut w = self.writer.lock().unwrap();
        let _ = writeln!(w, "{line}");
    }

    /// Flush buffered lines to disk (end of run; `papas watch` readers
    /// only see flushed lines).
    pub fn flush(&self) {
        let _ = self.writer.lock().unwrap().flush();
    }

    /// Fold one attempt's sampled resource telemetry into the registry
    /// (skipped entirely for unsampled all-zero attempts, so non-Linux
    /// journals don't grow empty histograms).
    fn fold_resources(
        &self,
        cpu_secs: f64,
        max_rss_kb: u64,
        io_read_bytes: u64,
        io_write_bytes: u64,
    ) {
        if cpu_secs == 0.0
            && max_rss_kb == 0
            && io_read_bytes == 0
            && io_write_bytes == 0
        {
            return;
        }
        let m = &self.metrics;
        m.observe("task_cpu_s", cpu_secs);
        m.observe("task_rss_kb", max_rss_kb as f64);
        m.add("io_read_bytes", io_read_bytes);
        m.add("io_write_bytes", io_write_bytes);
    }

    /// Fold one event into the metrics registry.
    fn fold(&self, ev: &TraceEvent) {
        let m = &self.metrics;
        match ev {
            TraceEvent::Header { workers, .. } => {
                m.set_gauge("workers", *workers as f64);
            }
            TraceEvent::Dispatch { key, .. } => {
                m.inc("tasks_dispatched");
                self.dispatched
                    .lock()
                    .unwrap()
                    .insert(key.clone(), self.clock.now());
            }
            TraceEvent::LptPick { pool_depth, .. } => {
                m.inc("lpt_picks");
                m.set_gauge("pool_depth", *pool_depth as f64);
            }
            TraceEvent::Complete {
                key,
                worker,
                ok,
                duration,
                start,
                class,
                cpu_secs,
                max_rss_kb,
                io_read_bytes,
                io_write_bytes,
                ..
            } => {
                m.inc(if *ok { "tasks_ok" } else { "tasks_failed" });
                if let Some(c) = class {
                    m.inc(&format!("class.{}", c.label()));
                }
                m.observe("task_duration_s", *duration);
                m.observe(&format!("worker_busy_s.{worker}"), *duration);
                if let Some(d) = self.dispatched.lock().unwrap().remove(key) {
                    m.observe("queue_wait_s", (start - d).max(0.0));
                }
                self.fold_resources(
                    *cpu_secs,
                    *max_rss_kb,
                    *io_read_bytes,
                    *io_write_bytes,
                );
            }
            TraceEvent::Retry { .. } => m.inc("retries"),
            TraceEvent::TimeoutKill { .. } => m.inc("timeout_kills"),
            TraceEvent::InferTimeout { .. } => m.inc("inferred_timeouts"),
            TraceEvent::WindowGrow { to, .. } => {
                m.inc("window_grows");
                m.set_gauge("window_size", *to as f64);
            }
            TraceEvent::WindowResize { to, .. } => {
                m.inc("window_resizes");
                m.set_gauge("window_size", *to as f64);
            }
            TraceEvent::CheckpointCommit { keys } => {
                m.inc("checkpoint_commits");
                m.set_gauge("checkpoint_keys", *keys as f64);
            }
            TraceEvent::Harvest { rows } => {
                m.inc("harvests");
                m.set_gauge("result_rows", *rows as f64);
            }
            TraceEvent::RunEnd => {}
            TraceEvent::SearchPropose { n, .. } => {
                m.add("search_proposed", *n as u64);
            }
            TraceEvent::SearchScore { scored, .. } => {
                m.add("search_scored", *scored as u64);
            }
        }
    }
}

/// A panicking run (or any path that skips the explicit end-of-run
/// `flush()`) must still leave a readable journal tail: `BufWriter`'s
/// own drop flushes, but only if the sink itself is dropped while the
/// mutex is healthy — flush explicitly so a poisoned lock (a panic on
/// another thread mid-`emit`) degrades to best-effort instead of
/// silently discarding the buffer.
impl Drop for TraceSink {
    fn drop(&mut self) {
        if let Ok(mut w) = self.writer.lock() {
            let _ = w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::clock::ScriptedClock;
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("papas_obs_journal").join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn complete(key: &str, worker: &str, start: f64, end: f64) -> TraceEvent {
        TraceEvent::Complete {
            key: key.to_string(),
            task_id: key.split('#').next().unwrap().to_string(),
            instance: 0,
            worker: worker.to_string(),
            attempt: 1,
            ok: true,
            duration: end - start,
            start,
            end,
            class: None,
            cpu_secs: 0.0,
            max_rss_kb: 0,
            io_read_bytes: 0,
            io_write_bytes: 0,
        }
    }

    #[test]
    fn emit_read_round_trip_and_metrics_fold() {
        let dir = tmp("roundtrip");
        let path = trace_path(&dir, 0);
        let clock = Arc::new(ScriptedClock::new());
        let sink = TraceSink::create(&path, clock.clone()).unwrap();
        sink.emit(&TraceEvent::Header {
            run: 0,
            study: "s".into(),
            workers: 2,
            n_instances: 3,
            epoch_unix: 0.0,
        });
        sink.emit(&TraceEvent::Dispatch { key: "t#0".into(), instance: 0 });
        clock.advance(2.0);
        sink.emit(&complete("t#0", "local-0", 0.0, 2.0));
        sink.emit(&TraceEvent::RunEnd);
        sink.flush();
        let events = read_trace(&path).unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].expect_str("ev").unwrap(), "header");
        assert_eq!(events[0].expect_i64("version").unwrap(), 1);
        assert_eq!(events[3].expect_str("ev").unwrap(), "run_end");
        // metrics folded as events were emitted
        let m = sink.metrics();
        assert_eq!(m.counter("tasks_dispatched"), 1);
        assert_eq!(m.counter("tasks_ok"), 1);
        assert_eq!(m.hist("task_duration_s").unwrap().n, 1);
        assert_eq!(m.hist("worker_busy_s.local-0").unwrap().sum, 2.0);
        // queue wait = start(0.0) − dispatch ts(0.0)
        assert_eq!(m.hist("queue_wait_s").unwrap().max, 0.0);
    }

    #[test]
    fn offline_fold_matches_the_live_sink() {
        let dir = tmp("parity");
        let path = trace_path(&dir, 0);
        let clock = Arc::new(ScriptedClock::new());
        let sink = TraceSink::create(&path, clock.clone()).unwrap();
        sink.emit(&TraceEvent::Header {
            run: 0,
            study: "s".into(),
            workers: 2,
            n_instances: 2,
            epoch_unix: 0.0,
        });
        sink.emit(&TraceEvent::Dispatch { key: "t#0".into(), instance: 0 });
        clock.advance(1.5);
        let mut done = complete("t#0", "local-1", 0.0, 1.5);
        if let TraceEvent::Complete { cpu_secs, max_rss_kb, .. } = &mut done
        {
            *cpu_secs = 0.75;
            *max_rss_kb = 4096;
        }
        sink.emit(&done);
        sink.emit(&TraceEvent::Retry {
            key: "t#1".into(),
            attempt: 1,
            backoff_ms: 100,
            class: None,
        });
        sink.emit(&TraceEvent::WindowResize { from: 4, to: 8, cov: 0.2 });
        sink.emit(&TraceEvent::Harvest { rows: 2 });
        sink.emit(&TraceEvent::RunEnd);
        sink.flush();
        let events = read_trace(&path).unwrap();
        let offline = fold_trace(&events);
        assert_eq!(
            crate::json::to_string(&offline.snapshot()),
            crate::json::to_string(&sink.metrics().snapshot()),
        );
        assert_eq!(offline.hist("task_cpu_s").unwrap().sum, 0.75);
        assert_eq!(offline.hist("task_rss_kb").unwrap().max, 4096.0);
    }

    #[test]
    fn torn_trailing_line_is_skipped() {
        let dir = tmp("torn");
        let path = trace_path(&dir, 1);
        let sink =
            TraceSink::create(&path, Arc::new(ScriptedClock::new())).unwrap();
        sink.emit(&TraceEvent::RunEnd);
        sink.flush();
        // simulate a crash mid-write
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"ts\":1.0,\"ev\":\"disp");
        std::fs::write(&path, text).unwrap();
        let events = read_trace(&path).unwrap();
        assert_eq!(events.len(), 1);
    }

    #[test]
    fn latest_trace_run_scans_the_db_root() {
        let dir = tmp("latest");
        assert_eq!(latest_trace_run(&dir), None);
        for run in [0u32, 2, 1] {
            TraceSink::create(
                &trace_path(&dir, run),
                Arc::new(ScriptedClock::new()),
            )
            .unwrap()
            .flush();
        }
        std::fs::write(dir.join("trace-search.jsonl"), "").unwrap();
        assert_eq!(latest_trace_run(&dir), Some(2));
    }
}
