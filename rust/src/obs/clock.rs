//! Run clocks: real monotonic time vs scripted logical time.
//!
//! Trace timestamps must be *replayable*: a hermetic `ScriptedExecutor`
//! run that re-emits the identical event sequence should produce a
//! byte-identical `trace.jsonl`. Wall clocks cannot deliver that, so
//! the trace sink reads time through this trait — [`MonotonicClock`]
//! on live runs, [`ScriptedClock`] (advanced by simulated task
//! durations) on deterministic replays.

use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// A source of run-relative timestamps for the trace sink.
pub trait Clock: Send + Sync {
    /// Seconds since the run epoch.
    fn now(&self) -> f64;

    /// Wall-clock UNIX seconds of the run epoch (0.0 for scripted
    /// clocks, which have no wall anchor — keeping replays
    /// byte-deterministic).
    fn epoch_unix(&self) -> f64;

    /// Advance logical time by `secs` (no-op for real clocks).
    fn advance(&self, _secs: f64) {}
}

/// The real clock: monotonic offsets anchored to a wall-clock epoch,
/// so timelines from different runs/shards can be aligned post hoc.
#[derive(Debug)]
pub struct MonotonicClock {
    epoch: Instant,
    epoch_unix: f64,
}

impl MonotonicClock {
    /// New clock; the epoch is "now".
    pub fn new() -> MonotonicClock {
        MonotonicClock {
            epoch: Instant::now(),
            epoch_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0),
        }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn epoch_unix(&self) -> f64 {
        self.epoch_unix
    }
}

/// A scripted logical clock: starts at 0.0 and only moves when
/// [`Clock::advance`] is called (the scripted executor advances it by
/// each attempt's simulated duration). Two replays of the same script
/// therefore stamp identical timestamps.
#[derive(Debug)]
pub struct ScriptedClock {
    t: Mutex<f64>,
}

impl ScriptedClock {
    /// New clock at logical time 0.0.
    pub fn new() -> ScriptedClock {
        ScriptedClock { t: Mutex::new(0.0) }
    }
}

impl Default for ScriptedClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for ScriptedClock {
    fn now(&self) -> f64 {
        *self.t.lock().unwrap()
    }

    fn epoch_unix(&self) -> f64 {
        0.0
    }

    fn advance(&self, secs: f64) {
        *self.t.lock().unwrap() += secs.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_advances_and_has_a_wall_anchor() {
        let c = MonotonicClock::new();
        let t0 = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now() > t0);
        assert!(c.epoch_unix() > 0.0);
        c.advance(100.0); // no-op on real clocks
        assert!(c.now() < 50.0);
    }

    #[test]
    fn scripted_clock_is_logical_and_deterministic() {
        let c = ScriptedClock::new();
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.epoch_unix(), 0.0);
        c.advance(1.5);
        c.advance(2.0);
        assert_eq!(c.now(), 3.5);
        c.advance(-4.0); // negative advances are clamped out
        assert_eq!(c.now(), 3.5);
    }
}
