//! INI-dialect parser for PaPaS parameter files (§4.1: "parameter files
//! follow either YAML, JSON, or INI-like data serialization formats with
//! minor constraints").
//!
//! Dialect, mapped onto the two-level WDL structure:
//!
//! ```ini
//! [matmulOMP]                       ; a task section
//! name = Matrix multiply scaling study
//! command = matmul ${args:size} out.txt
//!
//! [matmulOMP.environ]               ; dotted subsection = nested mapping
//! OMP_NUM_THREADS = 1:8             ; values may be comma-separated lists
//!
//! [matmulOMP.args]
//! size = 16:*2:16384
//! ```
//!
//! * `;` and `#` start comments (full-line or after whitespace);
//! * `key = value`; a comma-separated value parses to a sequence
//!   (quoting protects commas);
//! * `[section]` and one dotted level `[section.sub]`;
//! * keys before any section header go to the document root.

use crate::util::error::{Error, Location, Result};
use crate::util::strings::{split_top_level, unquote};
use crate::wdl::doc::Node;

/// Parse an INI document into the common node model.
pub fn parse(src: &str) -> Result<Node> {
    let mut root: Vec<(String, Node)> = Vec::new();
    // Path of the currently-open section (0, 1, or 2 components).
    let mut path: Vec<String> = Vec::new();

    for (i, raw) in src.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(Error::parse(
                    Location::new(lineno, 1),
                    "unterminated section header",
                ));
            }
            let name = line[1..line.len() - 1].trim();
            if name.is_empty() {
                return Err(Error::parse(
                    Location::new(lineno, 1),
                    "empty section name",
                ));
            }
            path = name.split('.').map(|s| s.trim().to_string()).collect();
            if path.len() > 2 || path.iter().any(|p| p.is_empty()) {
                return Err(Error::parse(
                    Location::new(lineno, 1),
                    format!("invalid section path '{name}' (at most one dot)"),
                ));
            }
            // Ensure the section exists even if empty.
            ensure_path(&mut root, &path);
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(Error::parse(
                Location::new(lineno, 1),
                format!("expected 'key = value', found '{line}'"),
            ));
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(Error::parse(Location::new(lineno, 1), "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim());
        let target = ensure_path(&mut root, &path);
        if target.iter().any(|(k, _)| k == key) {
            return Err(Error::parse(
                Location::new(lineno, 1),
                format!("duplicate key '{key}'"),
            ));
        }
        target.push((key.to_string(), value));
    }
    Ok(Node::Map(root))
}

/// Walk/create the mapping at `path` inside the root entry list and
/// return it for insertion.
fn ensure_path<'a>(
    root: &'a mut Vec<(String, Node)>,
    path: &[String],
) -> &'a mut Vec<(String, Node)> {
    let mut cur = root;
    for comp in path {
        let idx = match cur.iter().position(|(k, _)| k == comp) {
            Some(i) => i,
            None => {
                cur.push((comp.clone(), Node::Map(Vec::new())));
                cur.len() - 1
            }
        };
        cur = match &mut cur[idx].1 {
            Node::Map(m) => m,
            // A scalar was already stored under this name; replace with a
            // map (last-write-wins is the INI convention for sections).
            slot => {
                *slot = Node::Map(Vec::new());
                match slot {
                    Node::Map(m) => m,
                    _ => unreachable!(),
                }
            }
        };
    }
    cur
}

/// `a, b, c` becomes a sequence; a single token stays scalar.
fn parse_value(v: &str) -> Node {
    let parts = split_top_level(v, ',');
    if parts.len() > 1 {
        Node::Seq(
            parts
                .iter()
                .map(|p| Node::scalar(unquote(p.trim())))
                .collect(),
        )
    } else {
        Node::scalar(unquote(v))
    }
}

/// Comments: `;` or `#` at line start or preceded by whitespace, outside
/// quotes.
fn strip_comment(s: &str) -> &str {
    let mut in_single = false;
    let mut in_double = false;
    for (i, c) in s.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            ';' | '#' if !in_single && !in_double => {
                if i == 0 || s[..i].ends_with(' ') || s[..i].ends_with('\t') {
                    return &s[..i];
                }
            }
            _ => {}
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = "\
; PaPaS INI study
[matmulOMP]
name = Matrix multiply scaling study
command = matmul ${args:size} result_${args:size}N.txt

[matmulOMP.environ]
OMP_NUM_THREADS = 1:8

[matmulOMP.args]
size = 16, 32, 64
";

    #[test]
    fn parses_sections_and_subsections() {
        let doc = parse(EXAMPLE).unwrap();
        let task = doc.get("matmulOMP").unwrap();
        assert_eq!(
            task.get("name").unwrap().as_scalar(),
            Some("Matrix multiply scaling study")
        );
        assert_eq!(
            task.get("environ").unwrap().get("OMP_NUM_THREADS").unwrap().as_scalar(),
            Some("1:8")
        );
        let sizes = task.get("args").unwrap().get("size").unwrap().as_seq().unwrap();
        assert_eq!(sizes.len(), 3);
        assert_eq!(sizes[2].as_scalar(), Some("64"));
    }

    #[test]
    fn root_level_keys() {
        let doc = parse("global = 1\n[s]\nk = v\n").unwrap();
        assert_eq!(doc.get("global").unwrap().as_scalar(), Some("1"));
        assert_eq!(doc.get("s").unwrap().get("k").unwrap().as_scalar(), Some("v"));
    }

    #[test]
    fn quoted_values_protect_commas_and_comments() {
        let doc = parse("k = 'a, b' ; note\nj = \"x # y\"\n").unwrap();
        assert_eq!(doc.get("k").unwrap().as_scalar(), Some("a, b"));
        assert_eq!(doc.get("j").unwrap().as_scalar(), Some("x # y"));
    }

    #[test]
    fn empty_section_is_empty_map() {
        let doc = parse("[empty]\n").unwrap();
        assert_eq!(doc.get("empty").unwrap().as_map().unwrap().len(), 0);
    }

    #[test]
    fn errors() {
        assert!(parse("[bad\n").is_err());
        assert!(parse("[]\n").is_err());
        assert!(parse("[a.b.c]\n").is_err());
        assert!(parse("no equals here\n").is_err());
        assert!(parse("= v\n").is_err());
        assert!(parse("[s]\nk = 1\nk = 2\n").is_err());
    }

    #[test]
    fn interpolation_braces_survive() {
        let doc = parse("cmd = run ${args:size} ${env:T}\n").unwrap();
        assert_eq!(
            doc.get("cmd").unwrap().as_scalar(),
            Some("run ${args:size} ${env:T}")
        );
    }
}
