//! Task executors: how ready tasks actually run.
//!
//! Three backends, matching the paper's `parallel` keyword (§5):
//!
//! * [`local`] — a worker thread pool on this machine (laptop /
//!   workstation mode, the paper's default);
//! * [`mpi`] — the C++-MPI-style task dispatcher (§4.3): one master rank
//!   assigns tasks to N×P worker ranks over a message-passing protocol —
//!   the mechanism PaPaS uses to group many user tasks into one cluster
//!   job;
//! * [`ssh`] — worker daemons on (un)managed hosts reached over a socket
//!   protocol; here the daemons are separate OS processes on localhost,
//!   preserving the process/wire topology without a real cluster.
//!
//! All backends consume ready tasks from a channel and report completions
//! on another; the [`crate::workflow::scheduler`] drives dependency
//! resolution above them, so scheduling policy and transport are fully
//! decoupled.
//!
//! A fourth backend, [`scripted`], replays a deterministic script of
//! outcomes through the same worker loop — the hermetic test double for
//! the whole fault path (timeouts, retries, failure policies, resume).
//! The fault vocabulary itself ([`ErrorClass`], [`FailurePolicy`],
//! backoff) lives in [`fault`].

pub mod fault;
pub mod local;
pub mod mpi;
pub mod runner;
pub mod scripted;
pub mod ssh;

pub use fault::{backoff_delay, ErrorClass, FailurePolicy};
pub use runner::{RunConfig, TaskResult, TaskRunner};
pub use scripted::{Outcome, Script, ScriptedExecutor};

use crate::workflow::ConcreteTask;
use crate::util::error::Result;
use std::sync::mpsc::{Receiver, Sender};

/// Executes one task to completion, synchronously. [`TaskRunner`] is the
/// production implementation (staging, builtins, subprocesses with
/// timeout kill + reap); [`Script`] is the deterministic in-process
/// implementation the hermetic tests run against. Worker pools are
/// generic over this, so parallelism/ordering invariants are testable
/// without spawning anything.
pub trait TaskExec: Send + Sync {
    /// Run `task`, never panicking on task failure — all failures land
    /// in the result.
    fn exec(&self, task: &ConcreteTask) -> TaskResult;
}

/// A completed task notification.
pub type Completion = (ConcreteTask, TaskResult);

/// An execution backend. `run_all` consumes tasks until the channel
/// closes, sending one completion per task; it returns once all accepted
/// tasks have completed. `Sync` because the scheduler calls it from a
/// scoped thread while retaining a shared reference.
pub trait Executor: Sync {
    /// Backend name for provenance records.
    fn name(&self) -> &'static str;
    /// Number of concurrent workers.
    fn workers(&self) -> usize;
    /// Drain `ready`, executing every task and reporting on `done`.
    fn run_all(
        &self,
        ready: Receiver<ConcreteTask>,
        done: Sender<Completion>,
    ) -> Result<()>;
}
