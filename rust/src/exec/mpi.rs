//! The MPI-style task dispatcher (§4.3: "the main mechanism for grouping
//! tasks as single jobs is using a C++ MPI task dispatcher").
//!
//! Faithful master/worker MPI shape, transport swapped for in-process
//! channels (DESIGN.md substitution table):
//!
//! * rank 0 is the master: it seeds every worker with one task, then
//!   reassigns dynamically as DONE messages arrive (first-come
//!   first-served self-scheduling — the classic MPI dispatcher loop);
//! * ranks 1..=N×P are workers: `Recv(ASSIGN|STOP)` → run → `Send(DONE)`;
//! * messages carry MPI-like tags so the protocol reads like the C++ it
//!   replaces.
//!
//! The rank topology mirrors the paper's grouping schemes: a job with
//! N nodes × P processes-per-node runs N·P worker ranks; `rank_host`
//! reports which simulated node a rank lives on (provenance + the Fig 3/4
//! per-node traces).

use super::runner::TaskRunner;
use super::{Completion, Executor};
use crate::util::error::{Error, Result};
use crate::workflow::ConcreteTask;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;

/// Message tags, mirroring the C++ dispatcher's MPI tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tag {
    /// Master → worker: here is a task.
    Assign,
    /// Worker → master: task finished (payload: the completion).
    Done,
    /// Master → worker: no more work, exit.
    Stop,
}

/// Master → worker message.
enum ToWorker {
    Assign(ConcreteTask),
    Stop,
}

/// Worker → master message.
struct FromWorker {
    rank: usize,
    completion: Completion,
}

/// Dispatcher configuration: the paper's N×P grouping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grouping {
    /// Simulated nodes in the cluster job (`nnodes`).
    pub nnodes: usize,
    /// Worker processes per node (`ppnode`).
    pub ppnode: usize,
}

impl Grouping {
    /// Total worker ranks (excluding the rank-0 master).
    pub fn ranks(&self) -> usize {
        self.nnodes * self.ppnode
    }

    /// The simulated node a worker rank (1-based) lives on.
    pub fn rank_host(&self, rank: usize) -> usize {
        assert!(rank >= 1 && rank <= self.ranks(), "worker rank {rank}");
        (rank - 1) / self.ppnode
    }
}

/// The MPI-style dispatcher.
pub struct MpiDispatcher {
    runner: Arc<TaskRunner>,
    grouping: Grouping,
}

impl MpiDispatcher {
    /// New dispatcher with the given N×P grouping.
    pub fn new(runner: Arc<TaskRunner>, grouping: Grouping) -> Result<Self> {
        if grouping.nnodes == 0 || grouping.ppnode == 0 {
            return Err(Error::Exec("grouping needs nnodes, ppnode >= 1".into()));
        }
        Ok(MpiDispatcher { runner, grouping })
    }

    /// The grouping in effect.
    pub fn grouping(&self) -> Grouping {
        self.grouping
    }
}

impl Executor for MpiDispatcher {
    fn name(&self) -> &'static str {
        "mpi"
    }

    fn workers(&self) -> usize {
        self.grouping.ranks()
    }

    fn run_all(
        &self,
        ready: Receiver<ConcreteTask>,
        done: Sender<Completion>,
    ) -> Result<()> {
        let nworkers = self.grouping.ranks();
        // Per-worker ASSIGN channels + one shared DONE channel: the
        // channel-set *is* the MPI communicator here.
        let mut to_workers: Vec<Sender<ToWorker>> = Vec::with_capacity(nworkers);
        let (from_tx, from_rx) = mpsc::channel::<FromWorker>();

        std::thread::scope(|s| -> Result<()> {
            for rank in 1..=nworkers {
                let (tx, rx) = mpsc::channel::<ToWorker>();
                to_workers.push(tx);
                let from_tx = from_tx.clone();
                let runner = self.runner.clone();
                let host = self.grouping.rank_host(rank);
                s.spawn(move || {
                    // Worker rank loop: Recv → run → Send(DONE).
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ToWorker::Stop => break,
                            ToWorker::Assign(task) => {
                                let mut result = runner.run(&task);
                                result.worker = format!("rank{rank}@node{host}");
                                if from_tx
                                    .send(FromWorker { rank, completion: (task, result) })
                                    .is_err()
                                {
                                    break;
                                }
                            }
                        }
                    }
                });
            }
            drop(from_tx);

            // ---- master (rank 0) ----
            // FIFO idle queue: ranks recycle round-robin, spreading work
            // across nodes instead of re-hitting the most recent rank.
            let mut idle: std::collections::VecDeque<usize> =
                (1..=nworkers).collect();
            let mut in_flight = 0usize;
            let mut ready_closed = false;

            loop {
                // Assign while we have both an idle rank and a ready task.
                while !idle.is_empty() && !ready_closed {
                    match ready.try_recv() {
                        Ok(task) => {
                            let rank = idle.pop_front().unwrap();
                            to_workers[rank - 1]
                                .send(ToWorker::Assign(task))
                                .map_err(|_| {
                                    Error::Exec(format!("rank {rank} died"))
                                })?;
                            in_flight += 1;
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            ready_closed = true;
                        }
                    }
                }

                if in_flight == 0 {
                    if ready_closed {
                        break;
                    }
                    // All ranks idle; block for more work.
                    match ready.recv() {
                        Ok(task) => {
                            let rank = idle.pop_front().expect("all idle");
                            to_workers[rank - 1]
                                .send(ToWorker::Assign(task))
                                .map_err(|_| {
                                    Error::Exec(format!("rank {rank} died"))
                                })?;
                            in_flight += 1;
                        }
                        Err(_) => break, // closed and drained
                    }
                    continue;
                }

                // Wait for a DONE, then recycle the rank. std mpsc has no
                // select: when idle ranks remain and the ready stream is
                // still open, new work can arrive *while* we wait, so
                // bound the wait and re-poll the ready channel — blocking
                // indefinitely here serializes trickle-fed queues onto one
                // rank (found by the DFS-admission tests).
                let msg = if !idle.is_empty() && !ready_closed {
                    match from_rx.recv_timeout(std::time::Duration::from_millis(1)) {
                        Ok(m) => Some(m),
                        Err(mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                } else {
                    match from_rx.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break,
                    }
                };
                if let Some(FromWorker { rank, completion }) = msg {
                    in_flight -= 1;
                    idle.push_back(rank);
                    if done.send(completion).is_err() {
                        break;
                    }
                }
            }

            // STOP all ranks.
            for tx in &to_workers {
                let _ = tx.send(ToWorker::Stop);
            }
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::runner::RunConfig;
    use crate::tasks::Builtins;
    use std::collections::BTreeMap;

    fn dispatcher(nnodes: usize, ppnode: usize) -> MpiDispatcher {
        let root = std::env::temp_dir().join("papas_mpi");
        std::fs::create_dir_all(&root).unwrap();
        MpiDispatcher::new(
            Arc::new(TaskRunner::new(
                Arc::new(Builtins::without_runtime()),
                RunConfig {
                    work_root: root.join("work"),
                    input_root: root.join("inputs"),
                },
            )),
            Grouping { nnodes, ppnode },
        )
        .unwrap()
    }

    fn sleep_task(i: u64, ms: u64) -> ConcreteTask {
        ConcreteTask {
            instance: i,
            task_id: "sim".into(),
            argv: vec!["sleep-ms".into(), ms.to_string()],
            env: BTreeMap::new(),
            infiles: vec![],
            outfiles: vec![],
            substitutions: vec![],
            timeout: None,
            retries: 0,
        }
    }

    #[test]
    fn grouping_topology() {
        let g = Grouping { nnodes: 2, ppnode: 2 };
        assert_eq!(g.ranks(), 4);
        assert_eq!(g.rank_host(1), 0);
        assert_eq!(g.rank_host(2), 0);
        assert_eq!(g.rank_host(3), 1);
        assert_eq!(g.rank_host(4), 1);
    }

    #[test]
    fn paper_grouping_schemes_run_25_tasks() {
        // The §6 case study: 25 simulations under 2N-2P.
        let d = dispatcher(2, 2);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        for i in 0..25 {
            tx.send(sleep_task(i, 1)).unwrap();
        }
        drop(tx);
        d.run_all(rx, dtx).unwrap();
        let results: Vec<Completion> = drx.into_iter().collect();
        assert_eq!(results.len(), 25);
        assert!(results.iter().all(|(_, r)| r.ok));
        // all 4 ranks participated and worker labels carry the node
        let workers: std::collections::BTreeSet<&str> =
            results.iter().map(|(_, r)| r.worker.as_str()).collect();
        assert_eq!(workers.len(), 4, "{workers:?}");
        assert!(workers.iter().any(|w| w.contains("@node0")));
        assert!(workers.iter().any(|w| w.contains("@node1")));
    }

    #[test]
    fn serial_grouping_1n_1p() {
        let d = dispatcher(1, 1);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        for i in 0..5 {
            tx.send(sleep_task(i, 0)).unwrap();
        }
        drop(tx);
        d.run_all(rx, dtx).unwrap();
        let results: Vec<Completion> = drx.into_iter().collect();
        assert_eq!(results.len(), 5);
        assert!(results.iter().all(|(_, r)| r.worker == "rank1@node0"));
    }

    #[test]
    fn dynamic_balancing_under_skew() {
        // One long task + many short ones: the long task must not
        // serialize the rest (dynamic self-scheduling property).
        let d = dispatcher(1, 2);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        tx.send(sleep_task(0, 50)).unwrap();
        for i in 1..9 {
            tx.send(sleep_task(i, 1)).unwrap();
        }
        drop(tx);
        let t0 = std::time::Instant::now();
        d.run_all(rx, dtx).unwrap();
        let elapsed = t0.elapsed().as_millis();
        assert_eq!(drx.into_iter().count(), 9);
        // serial would be ≥ 58ms on one rank; dynamic two-rank ≈ max(50, 8)
        assert!(elapsed < 150, "took {elapsed}ms");
    }

    #[test]
    fn zero_grouping_rejected() {
        let root = std::env::temp_dir();
        let runner = Arc::new(TaskRunner::new(
            Arc::new(Builtins::without_runtime()),
            RunConfig { work_root: root.clone(), input_root: root },
        ));
        assert!(MpiDispatcher::new(runner, Grouping { nnodes: 0, ppnode: 1 }).is_err());
    }
}
