//! Fault model of the execution engine: how task failures are
//! classified, when they are retried, and what the study does about
//! them.
//!
//! The paper positions PaPaS for multi-tenant systems where PaPaS "will
//! run as user processes" — task failures, stragglers, and preemption
//! are normal operating conditions there, not exceptions. This module
//! holds the three vocabulary types the rest of the engine shares:
//!
//! * [`ErrorClass`] — why an attempt failed (spawn / timeout / nonzero /
//!   killed), recorded verbatim in the per-task attempt log;
//! * [`FailurePolicy`] — the study-level reaction to a terminal task
//!   failure (`fail-fast` | `continue` | `retry-budget N`), settable via
//!   the WDL `on_failure` key or `papas run --on-failure`;
//! * [`backoff_delay`] — the exponential backoff schedule between retry
//!   attempts of one task.
//!
//! Per-task knobs (`timeout`, `retries`) travel on
//! [`crate::workflow::ConcreteTask`]; enforcement is split between the
//! runner (timeouts: kill + reap) and the scheduler (retries, policies).

use std::fmt;
use std::time::Duration;

/// Why a task attempt failed. `None` on a [`super::TaskResult`] means
/// the attempt succeeded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// The task never ran: spawn failure, staging error, empty argv.
    Spawn,
    /// The task exceeded its wall-clock `timeout` and was killed + reaped.
    Timeout,
    /// The task ran to completion with a non-zero exit code (or a
    /// builtin returned an error).
    NonZero,
    /// The task was terminated by an external signal.
    Killed,
}

impl ErrorClass {
    /// Stable lowercase label (attempt log, provenance, CLI output).
    pub fn label(self) -> &'static str {
        match self {
            ErrorClass::Spawn => "spawn",
            ErrorClass::Timeout => "timeout",
            ErrorClass::NonZero => "nonzero",
            ErrorClass::Killed => "killed",
        }
    }

    /// Parse a stable label back (attempt-log deserialization).
    pub fn parse(s: &str) -> Option<ErrorClass> {
        match s {
            "spawn" => Some(ErrorClass::Spawn),
            "timeout" => Some(ErrorClass::Timeout),
            "nonzero" => Some(ErrorClass::NonZero),
            "killed" => Some(ErrorClass::Killed),
            _ => None,
        }
    }
}

impl fmt::Display for ErrorClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Study-level reaction to a terminal task failure.
///
/// Declared once per study (the WDL `on_failure` key on any task — the
/// first declaration wins — or `papas run --on-failure ...`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FailurePolicy {
    /// Stop admitting work at the first terminal failure: pending
    /// retries are cancelled, no new instances enter the window, and the
    /// run drains what is already in flight. Retries never happen under
    /// fail-fast.
    FailFast,
    /// Record the failure, skip its dependents, keep going (the
    /// default). Tasks retry only when they declare `retries`.
    #[default]
    Continue,
    /// Like `continue`, plus a study-wide budget of N extra attempts
    /// shared by all failing tasks. A task with its own `retries` key is
    /// still capped per-task; a task without one may draw on the budget
    /// freely. Once the budget is spent, failures become terminal.
    RetryBudget(u32),
}

impl FailurePolicy {
    /// Parse `fail-fast` | `continue` | `retry-budget N` (also accepts
    /// `retry-budget:N` and `retry-budget=N`). Returns a plain message
    /// so callers can wrap it in their own subsystem error.
    pub fn parse(s: &str) -> std::result::Result<FailurePolicy, String> {
        let norm = s.trim().to_ascii_lowercase();
        match norm.as_str() {
            "fail-fast" | "failfast" | "fail_fast" => {
                return Ok(FailurePolicy::FailFast)
            }
            "continue" => return Ok(FailurePolicy::Continue),
            _ => {}
        }
        let rest = norm
            .strip_prefix("retry-budget")
            .or_else(|| norm.strip_prefix("retry_budget"))
            .ok_or_else(|| {
                format!(
                    "unknown failure policy '{s}' (expected fail-fast, \
                     continue, or retry-budget N)"
                )
            })?;
        let digits = rest.trim_start_matches([' ', ':', '=']).trim();
        digits
            .parse()
            .map(FailurePolicy::RetryBudget)
            .map_err(|_| {
                format!("retry-budget needs a non-negative count, got '{s}'")
            })
    }
}

impl fmt::Display for FailurePolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FailurePolicy::FailFast => f.write_str("fail-fast"),
            FailurePolicy::Continue => f.write_str("continue"),
            FailurePolicy::RetryBudget(n) => write!(f, "retry-budget {n}"),
        }
    }
}

/// Ceiling of the exponential backoff schedule: no retry ever waits
/// longer than 60 s, regardless of `base_ms` or attempt count.
///
/// `attempt` is user-controlled (`retries` / `retry-budget N` have no
/// upper bound), so [`backoff_delay`] must stay overflow-free for any
/// `u32` attempt: the doubling shift is clamped to 16 **before**
/// `1u64 << shift` (a shift ≥ 64 would be UB-adjacent wrap in release),
/// the multiply saturates, and the product is capped here.
pub const BACKOFF_CAP_MS: u64 = 60_000;

/// Delay before retry attempt `attempt + 1`, given that `attempt`
/// executions have already happened: `base × 2^(attempt-1)`, capped at
/// [`BACKOFF_CAP_MS`]. A zero base disables backoff entirely (the
/// hermetic-test configuration — no sleeps anywhere).
pub fn backoff_delay(base_ms: u64, attempt: u32) -> Duration {
    if base_ms == 0 {
        return Duration::ZERO;
    }
    let shift = attempt.saturating_sub(1).min(16);
    Duration::from_millis(base_ms.saturating_mul(1u64 << shift).min(BACKOFF_CAP_MS))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_class_labels_round_trip() {
        for c in [
            ErrorClass::Spawn,
            ErrorClass::Timeout,
            ErrorClass::NonZero,
            ErrorClass::Killed,
        ] {
            assert_eq!(ErrorClass::parse(c.label()), Some(c));
        }
        assert_eq!(ErrorClass::parse("exploded"), None);
    }

    #[test]
    fn policy_parse_accepts_all_spellings() {
        assert_eq!(
            FailurePolicy::parse("fail-fast").unwrap(),
            FailurePolicy::FailFast
        );
        assert_eq!(
            FailurePolicy::parse("continue").unwrap(),
            FailurePolicy::Continue
        );
        for s in ["retry-budget 5", "retry-budget:5", "retry-budget=5", "RETRY-BUDGET 5"] {
            assert_eq!(
                FailurePolicy::parse(s).unwrap(),
                FailurePolicy::RetryBudget(5),
                "{s}"
            );
        }
        assert!(FailurePolicy::parse("panic").is_err());
        assert!(FailurePolicy::parse("retry-budget lots").is_err());
    }

    #[test]
    fn policy_display_round_trips_through_parse() {
        for p in [
            FailurePolicy::FailFast,
            FailurePolicy::Continue,
            FailurePolicy::RetryBudget(7),
        ] {
            assert_eq!(FailurePolicy::parse(&p.to_string()).unwrap(), p);
        }
    }

    #[test]
    fn backoff_doubles_and_caps() {
        assert_eq!(backoff_delay(0, 1), Duration::ZERO);
        assert_eq!(backoff_delay(100, 1), Duration::from_millis(100));
        assert_eq!(backoff_delay(100, 2), Duration::from_millis(200));
        assert_eq!(backoff_delay(100, 3), Duration::from_millis(400));
        assert_eq!(backoff_delay(100, 32), Duration::from_millis(BACKOFF_CAP_MS));
        assert_eq!(backoff_delay(u64::MAX, 9), Duration::from_millis(BACKOFF_CAP_MS));
    }

    /// `attempt` comes straight from user-set retry budgets: the
    /// schedule must saturate at [`BACKOFF_CAP_MS`] — never wrap, shift
    /// out of range, or panic — all the way to `u32::MAX` attempts.
    #[test]
    fn backoff_saturates_at_extreme_attempt_counts() {
        let cap = Duration::from_millis(BACKOFF_CAP_MS);
        for attempt in [32, 64, 1_000_000, u32::MAX - 1, u32::MAX] {
            // even base 1 hits the cap: 1 × 2^16 = 65 536 ms > 60 000 ms
            assert_eq!(backoff_delay(1, attempt), cap);
            assert_eq!(backoff_delay(100, attempt), cap, "attempt {attempt}");
            assert_eq!(backoff_delay(u64::MAX, attempt), cap);
            assert_eq!(backoff_delay(0, attempt), Duration::ZERO);
        }
        // attempt 0 (first execution, nothing to back off from) and 1
        // both yield the base delay.
        assert_eq!(backoff_delay(250, 0), Duration::from_millis(250));
        assert_eq!(backoff_delay(250, 1), Duration::from_millis(250));
    }
}
