//! Single-task execution: staging, substitution, builtin dispatch or
//! subprocess spawn, output capture, wall-clock timeout enforcement
//! (kill + reap). Shared by every executor backend (and by the SSH
//! worker daemon on the far side of the wire).

use super::fault::ErrorClass;
use super::TaskExec;
use crate::tasks::Builtins;
use crate::util::error::{Error, Result};
use crate::util::stats::Stopwatch;
use crate::workflow::ConcreteTask;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How a runner executes tasks.
pub struct RunConfig {
    /// Root directory for instance workdirs (`wf-00000000/`, ...).
    pub work_root: PathBuf,
    /// Directory where declared `infiles` templates are found (staged
    /// from here into the workdir; the paper's NFS shared-input dir).
    pub input_root: PathBuf,
}

impl RunConfig {
    /// Workdir of one workflow instance. 8 digits keep names fixed-width
    /// and lexicographically ordered up to 100M instances. This is the
    /// write path and stays a pure string format — no filesystem probes
    /// per task. Read-only paths over possibly pre-widening databases
    /// (4-digit `wf-NNNN`) go through `filedb::resolve_instance_dir`;
    /// checkpoints from that one-commit-old layout are not resumable
    /// here — re-run with `--fresh` (outputs remain aggregatable).
    pub fn instance_dir(&self, instance: u64) -> PathBuf {
        self.work_root.join(format!("wf-{instance:08}"))
    }
}

/// Outcome of one task execution attempt.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskResult {
    /// Success flag (exit code 0 / builtin Ok).
    pub ok: bool,
    /// Exit code (0 for successful builtins, -1 for spawn failures,
    /// timeouts, and signal deaths).
    pub exit_code: i32,
    /// First ~4 KiB of stdout / builtin summary (provenance).
    pub stdout: String,
    /// Error description when `!ok`.
    pub error: Option<String>,
    /// Failure classification when `!ok` (spawn/timeout/nonzero/killed);
    /// `None` on success.
    pub class: Option<ErrorClass>,
    /// Wall-clock duration in seconds (the §4.2 task profiler's datum).
    pub duration: f64,
    /// Label of the worker that ran it (filled by the executor).
    pub worker: String,
    /// True when `stdout` was cut at the capture cap (~4 KiB) — the
    /// provenance record is a prefix, not the full output.
    pub stdout_truncated: bool,
    /// User + system CPU seconds sampled from `/proc/<pid>/stat`. All
    /// four resource fields are best-effort telemetry: populated by the
    /// timeout poll loop on Linux, 0 off-Linux, on sampling failure, on
    /// the blocking no-timeout path, and for in-process builtins.
    pub cpu_secs: f64,
    /// Peak resident set (KiB) sampled from `/proc/<pid>/statm`.
    pub max_rss_kb: u64,
    /// Storage-layer bytes read, from `/proc/<pid>/io`.
    pub io_read_bytes: u64,
    /// Storage-layer bytes written, from `/proc/<pid>/io`.
    pub io_write_bytes: u64,
}

impl TaskResult {
    pub(crate) fn failure(
        msg: String,
        duration: f64,
        class: ErrorClass,
    ) -> TaskResult {
        TaskResult {
            ok: false,
            exit_code: -1,
            stdout: String::new(),
            error: Some(msg),
            class: Some(class),
            duration,
            worker: String::new(),
            stdout_truncated: false,
            cpu_secs: 0.0,
            max_rss_kb: 0,
            io_read_bytes: 0,
            io_write_bytes: 0,
        }
    }

    pub(crate) fn set_resources(&mut self, u: crate::obs::ResourceUsage) {
        self.cpu_secs = u.cpu_secs;
        self.max_rss_kb = u.max_rss_kb;
        self.io_read_bytes = u.io_read_bytes;
        self.io_write_bytes = u.io_write_bytes;
    }
}

/// Executes single tasks; cheap to share across worker threads.
pub struct TaskRunner {
    builtins: Arc<Builtins>,
    config: RunConfig,
}

impl TaskRunner {
    /// New runner.
    pub fn new(builtins: Arc<Builtins>, config: RunConfig) -> TaskRunner {
        TaskRunner { builtins, config }
    }

    /// The run configuration.
    pub fn config(&self) -> &RunConfig {
        &self.config
    }

    /// Execute one task to completion (staging → run → result). Never
    /// panics on task failure; all failures land in the result.
    pub fn run(&self, task: &ConcreteTask) -> TaskResult {
        let sw = Stopwatch::start();
        match self.run_inner(task) {
            Ok(r) => r,
            // Pre-execution failures (staging, empty argv): the task
            // never started — classified as spawn.
            Err(e) => TaskResult::failure(
                e.to_string(),
                sw.elapsed_secs(),
                ErrorClass::Spawn,
            ),
        }
    }

    fn run_inner(&self, task: &ConcreteTask) -> Result<TaskResult> {
        let workdir = self.config.instance_dir(task.instance);
        std::fs::create_dir_all(&workdir)?;
        stage_inputs(task, &self.config.input_root, &workdir)?;

        let sw = Stopwatch::start();
        let argv0 = task
            .argv
            .first()
            .ok_or_else(|| Error::Exec(format!("task '{}' has empty argv", task.key())))?;

        if self.builtins.is_builtin(argv0) {
            // Builtins run in-process: a thread cannot be killed, so the
            // wall-clock `timeout` applies to subprocess tasks only.
            match self.builtins.run(&task.argv, &task.env, &workdir) {
                Ok(out) => Ok(TaskResult {
                    ok: true,
                    exit_code: 0,
                    stdout: out.summary,
                    error: None,
                    class: None,
                    duration: sw.elapsed_secs(),
                    worker: String::new(),
                    stdout_truncated: false,
                    cpu_secs: 0.0,
                    max_rss_kb: 0,
                    io_read_bytes: 0,
                    io_write_bytes: 0,
                }),
                Err(e) => Ok(TaskResult::failure(
                    e.to_string(),
                    sw.elapsed_secs(),
                    ErrorClass::NonZero,
                )),
            }
        } else {
            self.run_subprocess(task, &workdir, sw)
        }
    }

    fn run_subprocess(
        &self,
        task: &ConcreteTask,
        workdir: &Path,
        sw: Stopwatch,
    ) -> Result<TaskResult> {
        match task.timeout {
            None => self.run_subprocess_blocking(task, workdir, sw),
            Some(limit) => self.run_subprocess_deadline(task, workdir, sw, limit),
        }
    }

    /// The no-timeout path: one blocking `output()` call.
    fn run_subprocess_blocking(
        &self,
        task: &ConcreteTask,
        workdir: &Path,
        sw: Stopwatch,
    ) -> Result<TaskResult> {
        let output = std::process::Command::new(&task.argv[0])
            .args(&task.argv[1..])
            .envs(&task.env)
            .current_dir(workdir)
            .stdin(std::process::Stdio::null())
            .output();
        let duration = sw.elapsed_secs();
        match output {
            Ok(out) => Ok(classify_exit(out.status, &out.stdout, &out.stderr, duration)),
            Err(e) => Ok(TaskResult::failure(
                format!("spawn '{}': {e}", task.argv[0]),
                duration,
                ErrorClass::Spawn,
            )),
        }
    }

    /// The timeout path: spawn with piped output, drain the pipes on
    /// helper threads (a chatty child must not deadlock against the wait
    /// loop), poll `try_wait` until the deadline, then kill + reap.
    fn run_subprocess_deadline(
        &self,
        task: &ConcreteTask,
        workdir: &Path,
        sw: Stopwatch,
        limit: f64,
    ) -> Result<TaskResult> {
        use std::io::Read;
        use std::process::{Command, Stdio};

        let spawned = Command::new(&task.argv[0])
            .args(&task.argv[1..])
            .envs(&task.env)
            .current_dir(workdir)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn();
        let mut child = match spawned {
            Ok(c) => c,
            Err(e) => {
                return Ok(TaskResult::failure(
                    format!("spawn '{}': {e}", task.argv[0]),
                    sw.elapsed_secs(),
                    ErrorClass::Spawn,
                ))
            }
        };
        let mut out_pipe = child.stdout.take().expect("stdout piped");
        let mut err_pipe = child.stderr.take().expect("stderr piped");
        let out_h = std::thread::spawn(move || {
            let mut buf = Vec::new();
            let _ = out_pipe.read_to_end(&mut buf);
            buf
        });
        let err_h = std::thread::spawn(move || {
            let mut buf = Vec::new();
            let _ = err_pipe.read_to_end(&mut buf);
            buf
        });

        // Resource telemetry rides on the poll loop: one /proc sample
        // per wakeup, with the final read taken just before the reap.
        // Off-Linux the sampler is a permanent no-op (see obs::telemetry).
        let mut sampler = crate::obs::ResourceSampler::attach(child.id());
        let deadline = Instant::now() + Duration::from_secs_f64(limit.max(0.0));
        let mut poll = Duration::from_micros(200);
        let status = loop {
            match child.try_wait() {
                Ok(Some(st)) => break Some(st),
                Ok(None) => {
                    sampler.sample();
                    if Instant::now() >= deadline {
                        break None;
                    }
                    std::thread::sleep(poll);
                    // Escalate the poll interval: tight for short tasks,
                    // cheap for long ones.
                    poll = (poll * 2).min(Duration::from_millis(10));
                }
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait(); // reap
                    let _ = out_h.join();
                    let _ = err_h.join();
                    return Ok(TaskResult::failure(
                        format!("wait '{}': {e}", task.argv[0]),
                        sw.elapsed_secs(),
                        ErrorClass::Spawn,
                    ));
                }
            }
        };
        let usage = sampler.finish();
        if status.is_none() {
            // Timeout: kill, then wait() to reap — no zombie survives.
            let _ = child.kill();
            let _ = child.wait();
        }
        let stdout = out_h.join().unwrap_or_default();
        let stderr = err_h.join().unwrap_or_default();
        let duration = sw.elapsed_secs();
        match status {
            Some(st) => {
                let mut r = classify_exit(st, &stdout, &stderr, duration);
                r.set_resources(usage);
                Ok(r)
            }
            None => {
                let mut r = TaskResult::failure(
                    format!("timed out after {limit}s (killed + reaped)"),
                    duration,
                    ErrorClass::Timeout,
                );
                (r.stdout, r.stdout_truncated) = truncated(&stdout, 4096);
                r.set_resources(usage);
                Ok(r)
            }
        }
    }
}

impl TaskExec for TaskRunner {
    fn exec(&self, task: &ConcreteTask) -> TaskResult {
        self.run(task)
    }
}

/// Lossy-decode and cap captured output; the flag reports whether the
/// cap cut anything. The cap is a byte budget; the cut backs up to a
/// char boundary (a fixed-index `truncate` panics mid-UTF-8-character
/// and would kill the worker thread).
fn truncated(bytes: &[u8], cap: usize) -> (String, bool) {
    let mut s = String::from_utf8_lossy(bytes).into_owned();
    if s.len() > cap {
        let mut end = cap;
        while end > 0 && !s.is_char_boundary(end) {
            end -= 1;
        }
        s.truncate(end);
        return (s, true);
    }
    (s, false)
}

/// Build the result for a reaped exit status: success, non-zero exit, or
/// death by external signal (`code()` is `None`).
fn classify_exit(
    status: std::process::ExitStatus,
    stdout: &[u8],
    stderr: &[u8],
    duration: f64,
) -> TaskResult {
    let (stdout, stdout_truncated) = truncated(stdout, 4096);
    if status.success() {
        return TaskResult {
            ok: true,
            exit_code: 0,
            stdout,
            error: None,
            class: None,
            duration,
            worker: String::new(),
            stdout_truncated,
            cpu_secs: 0.0,
            max_rss_kb: 0,
            io_read_bytes: 0,
            io_write_bytes: 0,
        };
    }
    let (err_tail, _) = truncated(stderr, 1024);
    let (exit_code, class, error) = match status.code() {
        Some(code) => (
            code,
            ErrorClass::NonZero,
            format!("exit code {code}: {err_tail}"),
        ),
        None => (
            -1,
            ErrorClass::Killed,
            format!("killed by signal: {err_tail}"),
        ),
    };
    TaskResult {
        ok: false,
        exit_code,
        stdout,
        error: Some(error),
        class: Some(class),
        duration,
        worker: String::new(),
        stdout_truncated,
        cpu_secs: 0.0,
        max_rss_kb: 0,
        io_read_bytes: 0,
        io_write_bytes: 0,
    }
}

/// Stage declared infiles into the workdir, applying `substitute`
/// rewrites (§5: "simple regular expressions for file contents").
/// Identical inputs shared by all instances live once under
/// `input_root` — the paper's NFS-directory arrangement — and each
/// instance gets its own (possibly rewritten) copy.
fn stage_inputs(task: &ConcreteTask, input_root: &Path, workdir: &Path) -> Result<()> {
    for (_, rel) in &task.infiles {
        let src = input_root.join(rel);
        let dst = workdir.join(rel);
        if let Some(parent) = dst.parent() {
            std::fs::create_dir_all(parent)?;
        }
        if !src.exists() {
            // The file may be produced by an upstream task directly in
            // the workdir; staging only covers study-provided inputs.
            if dst.exists() {
                continue;
            }
            return Err(Error::Exec(format!(
                "task '{}': input file '{}' not found under {} or {}",
                task.key(),
                rel,
                input_root.display(),
                workdir.display()
            )));
        }
        if task.substitutions.is_empty() {
            std::fs::copy(&src, &dst)?;
            continue;
        }
        let mut content = std::fs::read_to_string(&src).map_err(|e| {
            Error::Exec(format!(
                "read '{}' for substitution: {e}",
                src.display()
            ))
        })?;
        for (pattern, replacement) in &task.substitutions {
            let re = regex::Regex::new(pattern).map_err(|e| {
                Error::Exec(format!("substitute regex '{pattern}': {e}"))
            })?;
            content = re.replace_all(&content, replacement.as_str()).into_owned();
        }
        std::fs::write(&dst, content)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn runner(root: &Path) -> TaskRunner {
        TaskRunner::new(
            Arc::new(Builtins::without_runtime()),
            RunConfig {
                work_root: root.join("work"),
                input_root: root.join("inputs"),
            },
        )
    }

    fn task(argv: &[&str]) -> ConcreteTask {
        ConcreteTask {
            instance: 0,
            task_id: "t".into(),
            argv: argv.iter().map(|s| s.to_string()).collect(),
            env: BTreeMap::new(),
            infiles: vec![],
            outfiles: vec![],
            substitutions: vec![],
            timeout: None,
            retries: 0,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("papas_runner").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn builtin_task_runs() {
        let root = tmp("builtin");
        let r = runner(&root);
        let res = r.run(&task(&["sleep-ms", "1"]));
        assert!(res.ok, "{res:?}");
        assert_eq!(res.exit_code, 0);
        assert_eq!(res.class, None);
        assert!(res.duration >= 0.0);
    }

    #[test]
    fn subprocess_success_and_failure() {
        let root = tmp("subproc");
        let r = runner(&root);
        let ok = r.run(&task(&["/bin/sh", "-c", "echo hello"]));
        assert!(ok.ok, "{ok:?}");
        assert!(ok.stdout.contains("hello"));
        assert_eq!(ok.class, None);

        let fail = r.run(&task(&["/bin/sh", "-c", "exit 3"]));
        assert!(!fail.ok);
        assert_eq!(fail.exit_code, 3);
        assert_eq!(fail.class, Some(ErrorClass::NonZero));

        let noexist = r.run(&task(&["/definitely/not/a/binary"]));
        assert!(!noexist.ok);
        assert!(noexist.error.as_deref().unwrap_or("").contains("spawn"));
        assert_eq!(noexist.class, Some(ErrorClass::Spawn));
    }

    #[test]
    fn env_reaches_subprocess() {
        let root = tmp("env");
        let r = runner(&root);
        let mut t = task(&["/bin/sh", "-c", "echo $PAPAS_X"]);
        t.env.insert("PAPAS_X".into(), "42".into());
        let res = r.run(&t);
        assert!(res.stdout.contains("42"), "{res:?}");
    }

    #[test]
    fn timeout_kills_and_reaps_hung_subprocess() {
        let root = tmp("timeout");
        let r = runner(&root);
        let mut t = task(&["/bin/sh", "-c", "echo started; sleep 30"]);
        t.timeout = Some(0.1);
        let sw = Stopwatch::start();
        let res = r.run(&t);
        let elapsed = sw.elapsed_secs();
        assert!(!res.ok, "{res:?}");
        assert_eq!(res.class, Some(ErrorClass::Timeout));
        assert_eq!(res.exit_code, -1);
        assert!(res.error.as_deref().unwrap().contains("timed out"));
        // partial output captured before the kill
        assert!(res.stdout.contains("started"), "{res:?}");
        // killed promptly — nowhere near the 30s sleep
        assert!(elapsed < 5.0, "took {elapsed}s");
    }

    #[test]
    fn fast_task_beats_its_timeout() {
        let root = tmp("fasttimeout");
        let r = runner(&root);
        let mut t = task(&["/bin/sh", "-c", "echo quick"]);
        t.timeout = Some(10.0);
        let res = r.run(&t);
        assert!(res.ok, "{res:?}");
        assert!(res.stdout.contains("quick"));
        // failures under a timeout still classify as nonzero
        let mut f = task(&["/bin/sh", "-c", "echo oops >&2; exit 7"]);
        f.timeout = Some(10.0);
        let res = r.run(&f);
        assert_eq!(res.exit_code, 7);
        assert_eq!(res.class, Some(ErrorClass::NonZero));
        assert!(res.error.as_deref().unwrap().contains("oops"));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn deadline_path_samples_proc_resources() {
        let root = tmp("telemetry");
        let r = runner(&root);
        // long enough for several poll-loop samples, far under the limit
        let mut t = task(&["/bin/sh", "-c", "sleep 0.05"]);
        t.timeout = Some(10.0);
        let res = r.run(&t);
        assert!(res.ok, "{res:?}");
        assert!(res.max_rss_kb > 0, "no RSS sampled: {res:?}");
        // blocking path (no timeout) takes no samples — fields stay 0
        let res = r.run(&task(&["/bin/sh", "-c", "true"]));
        assert!(res.ok);
        assert_eq!(res.max_rss_kb, 0);
        assert_eq!(res.cpu_secs, 0.0);
    }

    #[test]
    fn truncation_respects_char_boundaries() {
        // 2000 three-byte chars = 6000 bytes; 4096 % 3 == 1, so a naive
        // byte-index truncate would panic mid-character.
        let s = "€".repeat(2000);
        let (t, cut) = truncated(s.as_bytes(), 4096);
        assert!(t.len() <= 4096);
        assert!(!t.is_empty());
        assert!(cut);
        assert!(t.chars().all(|c| c == '€'));
        // short output passes through untouched
        assert_eq!(truncated("ok".as_bytes(), 4096), ("ok".to_string(), false));
    }

    #[test]
    fn stdout_cap_sets_truncated_flag() {
        let root = tmp("truncflag");
        let r = runner(&root);
        let long = r.run(&task(&[
            "/bin/sh",
            "-c",
            "head -c 9000 /dev/zero | tr '\\0' 'x'",
        ]));
        assert!(long.ok, "{long:?}");
        assert!(long.stdout_truncated);
        assert_eq!(long.stdout.len(), 4096);
        let short = r.run(&task(&["/bin/sh", "-c", "echo brief"]));
        assert!(short.ok);
        assert!(!short.stdout_truncated);
        assert!(short.stdout.contains("brief"));
    }

    #[test]
    fn signal_death_classified_as_killed() {
        let root = tmp("signal");
        let r = runner(&root);
        let res = r.run(&task(&["/bin/sh", "-c", "kill -9 $$"]));
        assert!(!res.ok);
        assert_eq!(res.class, Some(ErrorClass::Killed));
        assert_eq!(res.exit_code, -1);
    }

    #[test]
    fn staging_with_substitution() {
        let root = tmp("staging");
        std::fs::create_dir_all(root.join("inputs")).unwrap();
        std::fs::write(
            root.join("inputs/model.xml"),
            "<param beta=\"0.5\" gamma=\"1\"/>",
        )
        .unwrap();
        let r = runner(&root);
        let mut t = task(&["/bin/sh", "-c", "cat model.xml"]);
        t.infiles = vec![("model".into(), "model.xml".into())];
        t.substitutions =
            vec![("beta=\"[0-9.]+\"".into(), "beta=\"0.9\"".into())];
        let res = r.run(&t);
        assert!(res.ok, "{res:?}");
        assert!(res.stdout.contains("beta=\"0.9\""), "{}", res.stdout);
        assert!(res.stdout.contains("gamma=\"1\""));
        // original untouched
        let orig = std::fs::read_to_string(root.join("inputs/model.xml")).unwrap();
        assert!(orig.contains("beta=\"0.5\""));
    }

    #[test]
    fn missing_infile_fails_cleanly() {
        let root = tmp("missing");
        let r = runner(&root);
        let mut t = task(&["/bin/true"]);
        t.infiles = vec![("f".into(), "ghost.dat".into())];
        let res = r.run(&t);
        assert!(!res.ok);
        assert!(res.error.as_deref().unwrap().contains("ghost.dat"));
        assert_eq!(res.class, Some(ErrorClass::Spawn));
    }

    #[test]
    fn empty_argv_fails_cleanly() {
        let root = tmp("empty");
        let r = runner(&root);
        let res = r.run(&task(&[]));
        assert!(!res.ok);
        assert_eq!(res.class, Some(ErrorClass::Spawn));
    }
}
