//! Single-task execution: staging, substitution, builtin dispatch or
//! subprocess spawn, output capture. Shared by every executor backend
//! (and by the SSH worker daemon on the far side of the wire).

use crate::tasks::Builtins;
use crate::util::error::{Error, Result};
use crate::util::stats::Stopwatch;
use crate::workflow::ConcreteTask;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// How a runner executes tasks.
pub struct RunConfig {
    /// Root directory for instance workdirs (`wf-00000000/`, ...).
    pub work_root: PathBuf,
    /// Directory where declared `infiles` templates are found (staged
    /// from here into the workdir; the paper's NFS shared-input dir).
    pub input_root: PathBuf,
}

impl RunConfig {
    /// Workdir of one workflow instance. 8 digits keep names fixed-width
    /// and lexicographically ordered up to 100M instances. This is the
    /// write path and stays a pure string format — no filesystem probes
    /// per task. Read-only paths over possibly pre-widening databases
    /// (4-digit `wf-NNNN`) go through `filedb::resolve_instance_dir`;
    /// checkpoints from that one-commit-old layout are not resumable
    /// here — re-run with `--fresh` (outputs remain aggregatable).
    pub fn instance_dir(&self, instance: u64) -> PathBuf {
        self.work_root.join(format!("wf-{instance:08}"))
    }
}

/// Outcome of one task execution.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskResult {
    /// Success flag (exit code 0 / builtin Ok).
    pub ok: bool,
    /// Exit code (0 for successful builtins, -1 for spawn failures).
    pub exit_code: i32,
    /// First ~4 KiB of stdout / builtin summary (provenance).
    pub stdout: String,
    /// Error description when `!ok`.
    pub error: Option<String>,
    /// Wall-clock duration in seconds (the §4.2 task profiler's datum).
    pub duration: f64,
    /// Label of the worker that ran it (filled by the executor).
    pub worker: String,
}

impl TaskResult {
    fn failure(msg: String, duration: f64) -> TaskResult {
        TaskResult {
            ok: false,
            exit_code: -1,
            stdout: String::new(),
            error: Some(msg),
            duration,
            worker: String::new(),
        }
    }
}

/// Executes single tasks; cheap to share across worker threads.
pub struct TaskRunner {
    builtins: Arc<Builtins>,
    config: RunConfig,
}

impl TaskRunner {
    /// New runner.
    pub fn new(builtins: Arc<Builtins>, config: RunConfig) -> TaskRunner {
        TaskRunner { builtins, config }
    }

    /// The run configuration.
    pub fn config(&self) -> &RunConfig {
        &self.config
    }

    /// Execute one task to completion (staging → run → result). Never
    /// panics on task failure; all failures land in the result.
    pub fn run(&self, task: &ConcreteTask) -> TaskResult {
        let sw = Stopwatch::start();
        match self.run_inner(task) {
            Ok(r) => r,
            Err(e) => TaskResult::failure(e.to_string(), sw.elapsed_secs()),
        }
    }

    fn run_inner(&self, task: &ConcreteTask) -> Result<TaskResult> {
        let workdir = self.config.instance_dir(task.instance);
        std::fs::create_dir_all(&workdir)?;
        stage_inputs(task, &self.config.input_root, &workdir)?;

        let sw = Stopwatch::start();
        let argv0 = task
            .argv
            .first()
            .ok_or_else(|| Error::Exec(format!("task '{}' has empty argv", task.key())))?;

        if self.builtins.is_builtin(argv0) {
            match self.builtins.run(&task.argv, &task.env, &workdir) {
                Ok(out) => Ok(TaskResult {
                    ok: true,
                    exit_code: 0,
                    stdout: out.summary,
                    error: None,
                    duration: sw.elapsed_secs(),
                    worker: String::new(),
                }),
                Err(e) => Ok(TaskResult::failure(e.to_string(), sw.elapsed_secs())),
            }
        } else {
            self.run_subprocess(task, &workdir, sw)
        }
    }

    fn run_subprocess(
        &self,
        task: &ConcreteTask,
        workdir: &Path,
        sw: Stopwatch,
    ) -> Result<TaskResult> {
        let output = std::process::Command::new(&task.argv[0])
            .args(&task.argv[1..])
            .envs(&task.env)
            .current_dir(workdir)
            .stdin(std::process::Stdio::null())
            .output();
        let duration = sw.elapsed_secs();
        match output {
            Ok(out) => {
                let code = out.status.code().unwrap_or(-1);
                let mut stdout = String::from_utf8_lossy(&out.stdout).into_owned();
                stdout.truncate(4096);
                Ok(TaskResult {
                    ok: out.status.success(),
                    exit_code: code,
                    stdout,
                    error: if out.status.success() {
                        None
                    } else {
                        let mut err = String::from_utf8_lossy(&out.stderr).into_owned();
                        err.truncate(1024);
                        Some(format!("exit code {code}: {err}"))
                    },
                    duration,
                    worker: String::new(),
                })
            }
            Err(e) => Ok(TaskResult::failure(
                format!("spawn '{}': {e}", task.argv[0]),
                duration,
            )),
        }
    }
}

/// Stage declared infiles into the workdir, applying `substitute`
/// rewrites (§5: "simple regular expressions for file contents").
/// Identical inputs shared by all instances live once under
/// `input_root` — the paper's NFS-directory arrangement — and each
/// instance gets its own (possibly rewritten) copy.
fn stage_inputs(task: &ConcreteTask, input_root: &Path, workdir: &Path) -> Result<()> {
    for (_, rel) in &task.infiles {
        let src = input_root.join(rel);
        let dst = workdir.join(rel);
        if let Some(parent) = dst.parent() {
            std::fs::create_dir_all(parent)?;
        }
        if !src.exists() {
            // The file may be produced by an upstream task directly in
            // the workdir; staging only covers study-provided inputs.
            if dst.exists() {
                continue;
            }
            return Err(Error::Exec(format!(
                "task '{}': input file '{}' not found under {} or {}",
                task.key(),
                rel,
                input_root.display(),
                workdir.display()
            )));
        }
        if task.substitutions.is_empty() {
            std::fs::copy(&src, &dst)?;
            continue;
        }
        let mut content = std::fs::read_to_string(&src).map_err(|e| {
            Error::Exec(format!(
                "read '{}' for substitution: {e}",
                src.display()
            ))
        })?;
        for (pattern, replacement) in &task.substitutions {
            let re = regex::Regex::new(pattern).map_err(|e| {
                Error::Exec(format!("substitute regex '{pattern}': {e}"))
            })?;
            content = re.replace_all(&content, replacement.as_str()).into_owned();
        }
        std::fs::write(&dst, content)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn runner(root: &Path) -> TaskRunner {
        TaskRunner::new(
            Arc::new(Builtins::without_runtime()),
            RunConfig {
                work_root: root.join("work"),
                input_root: root.join("inputs"),
            },
        )
    }

    fn task(argv: &[&str]) -> ConcreteTask {
        ConcreteTask {
            instance: 0,
            task_id: "t".into(),
            argv: argv.iter().map(|s| s.to_string()).collect(),
            env: BTreeMap::new(),
            infiles: vec![],
            outfiles: vec![],
            substitutions: vec![],
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("papas_runner").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn builtin_task_runs() {
        let root = tmp("builtin");
        let r = runner(&root);
        let res = r.run(&task(&["sleep-ms", "1"]));
        assert!(res.ok, "{res:?}");
        assert_eq!(res.exit_code, 0);
        assert!(res.duration >= 0.0);
    }

    #[test]
    fn subprocess_success_and_failure() {
        let root = tmp("subproc");
        let r = runner(&root);
        let ok = r.run(&task(&["/bin/sh", "-c", "echo hello"]));
        assert!(ok.ok, "{ok:?}");
        assert!(ok.stdout.contains("hello"));

        let fail = r.run(&task(&["/bin/sh", "-c", "exit 3"]));
        assert!(!fail.ok);
        assert_eq!(fail.exit_code, 3);

        let noexist = r.run(&task(&["/definitely/not/a/binary"]));
        assert!(!noexist.ok);
        assert!(noexist.error.as_deref().unwrap_or("").contains("spawn"));
    }

    #[test]
    fn env_reaches_subprocess() {
        let root = tmp("env");
        let r = runner(&root);
        let mut t = task(&["/bin/sh", "-c", "echo $PAPAS_X"]);
        t.env.insert("PAPAS_X".into(), "42".into());
        let res = r.run(&t);
        assert!(res.stdout.contains("42"), "{res:?}");
    }

    #[test]
    fn staging_with_substitution() {
        let root = tmp("staging");
        std::fs::create_dir_all(root.join("inputs")).unwrap();
        std::fs::write(
            root.join("inputs/model.xml"),
            "<param beta=\"0.5\" gamma=\"1\"/>",
        )
        .unwrap();
        let r = runner(&root);
        let mut t = task(&["/bin/sh", "-c", "cat model.xml"]);
        t.infiles = vec![("model".into(), "model.xml".into())];
        t.substitutions =
            vec![("beta=\"[0-9.]+\"".into(), "beta=\"0.9\"".into())];
        let res = r.run(&t);
        assert!(res.ok, "{res:?}");
        assert!(res.stdout.contains("beta=\"0.9\""), "{}", res.stdout);
        assert!(res.stdout.contains("gamma=\"1\""));
        // original untouched
        let orig = std::fs::read_to_string(root.join("inputs/model.xml")).unwrap();
        assert!(orig.contains("beta=\"0.5\""));
    }

    #[test]
    fn missing_infile_fails_cleanly() {
        let root = tmp("missing");
        let r = runner(&root);
        let mut t = task(&["/bin/true"]);
        t.infiles = vec![("f".into(), "ghost.dat".into())];
        let res = r.run(&t);
        assert!(!res.ok);
        assert!(res.error.as_deref().unwrap().contains("ghost.dat"));
    }

    #[test]
    fn empty_argv_fails_cleanly() {
        let root = tmp("empty");
        let r = runner(&root);
        let res = r.run(&task(&[]));
        assert!(!res.ok);
    }
}
