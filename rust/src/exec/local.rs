//! Local thread-pool executor: the paper's laptop/workstation mode
//! ("PaPaS runs easily on a local laptop or workstation", §4.2).

use super::runner::TaskRunner;
use super::{Completion, Executor, TaskExec};
use crate::util::error::Result;
use crate::workflow::ConcreteTask;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

/// A fixed pool of worker threads pulling from the shared ready channel.
/// Generic over the single-task execution backend ([`TaskExec`]): the
/// production [`TaskRunner`], or a deterministic
/// [`super::scripted::Script`] in hermetic tests — the pool's
/// fan-out/ordering behavior is identical either way.
pub struct LocalPool {
    exec: Arc<dyn TaskExec>,
    workers: usize,
}

impl LocalPool {
    /// Pool with `workers` threads (min 1) over the production runner.
    pub fn new(runner: Arc<TaskRunner>, workers: usize) -> LocalPool {
        LocalPool::with_exec(runner, workers)
    }

    /// Pool over an arbitrary task-execution backend.
    pub fn with_exec(exec: Arc<dyn TaskExec>, workers: usize) -> LocalPool {
        LocalPool { exec, workers: workers.max(1) }
    }
}

impl Executor for LocalPool {
    fn name(&self) -> &'static str {
        "local"
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn run_all(
        &self,
        ready: Receiver<ConcreteTask>,
        done: Sender<Completion>,
    ) -> Result<()> {
        // mpsc receivers are single-consumer; share via a mutex so idle
        // workers block on the lock + recv (contention is negligible next
        // to task runtimes).
        let shared = Arc::new(Mutex::new(ready));
        std::thread::scope(|s| {
            for w in 0..self.workers {
                let shared = shared.clone();
                let done = done.clone();
                let exec = self.exec.clone();
                s.spawn(move || {
                    let label = format!("local-{w}");
                    loop {
                        let task = {
                            let rx = shared.lock().unwrap();
                            rx.recv()
                        };
                        let Ok(task) = task else { break }; // channel closed
                        let mut result = exec.exec(&task);
                        result.worker = label.clone();
                        if done.send((task, result)).is_err() {
                            break; // scheduler gone
                        }
                    }
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::runner::RunConfig;
    use crate::tasks::Builtins;
    use std::collections::BTreeMap;
    use std::sync::mpsc;

    fn pool(workers: usize) -> LocalPool {
        let root = std::env::temp_dir().join("papas_localpool");
        std::fs::create_dir_all(&root).unwrap();
        LocalPool::new(
            Arc::new(TaskRunner::new(
                Arc::new(Builtins::without_runtime()),
                RunConfig {
                    work_root: root.join("work"),
                    input_root: root.join("inputs"),
                },
            )),
            workers,
        )
    }

    fn sleep_task(i: u64, ms: u64) -> ConcreteTask {
        ConcreteTask {
            instance: i,
            task_id: "sleep".into(),
            argv: vec!["sleep-ms".into(), ms.to_string()],
            env: BTreeMap::new(),
            infiles: vec![],
            outfiles: vec![],
            substitutions: vec![],
            timeout: None,
            retries: 0,
        }
    }

    #[test]
    fn executes_all_tasks() {
        let p = pool(4);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        for i in 0..20 {
            tx.send(sleep_task(i, 1)).unwrap();
        }
        drop(tx);
        p.run_all(rx, dtx).unwrap();
        let results: Vec<Completion> = drx.into_iter().collect();
        assert_eq!(results.len(), 20);
        assert!(results.iter().all(|(_, r)| r.ok));
        // multiple workers were used
        let workers: std::collections::BTreeSet<&str> =
            results.iter().map(|(_, r)| r.worker.as_str()).collect();
        assert!(workers.len() > 1, "{workers:?}");
    }

    #[test]
    fn single_worker_is_serial_and_ordered() {
        let p = pool(1);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        for i in 0..5 {
            tx.send(sleep_task(i, 0)).unwrap();
        }
        drop(tx);
        p.run_all(rx, dtx).unwrap();
        let order: Vec<u64> = drx.into_iter().map(|(t, _)| t.instance).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn failures_are_reported_not_fatal() {
        let p = pool(2);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        let mut bad = sleep_task(0, 0);
        bad.argv = vec!["sleep-ms".into()]; // missing arg → failure
        tx.send(bad).unwrap();
        tx.send(sleep_task(1, 0)).unwrap();
        drop(tx);
        p.run_all(rx, dtx).unwrap();
        let results: Vec<Completion> = drx.into_iter().collect();
        assert_eq!(results.len(), 2);
        assert_eq!(results.iter().filter(|(_, r)| r.ok).count(), 1);
    }
}
