//! Deterministic in-process executor for hermetic fault testing.
//!
//! [`ScriptedExecutor`] is a real [`Executor`] — it sits behind the same
//! channel protocol and the same [`LocalPool`] worker loop as production
//! local execution — but instead of spawning subprocesses it consults a
//! [`Script`] of predetermined [`Outcome`]s: succeed, fail with an exit
//! code, fail N times then succeed, hang until the simulated timeout, or
//! fail to spawn. Durations are simulated, never slept, so every
//! retry/timeout/policy/resume path of the engine can be exercised with
//! no subprocesses and no wall-clock dependence.
//!
//! The script doubles as a journal: it counts executions per task key
//! and records the order in which tasks reached a worker, which is what
//! the `LocalPool` ordering/parallelism invariant tests assert against.

use super::local::LocalPool;
use super::runner::TaskResult;
use super::{Completion, ErrorClass, Executor, TaskExec};
use crate::obs::{Clock, ResourceUsage, ScriptedClock};
use crate::util::error::Result;
use crate::workflow::ConcreteTask;
use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

/// What happens when a scripted task reaches a worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Exit 0.
    Succeed,
    /// Exit with this (non-zero) code on every attempt.
    Fail(i32),
    /// Fail (exit 1) for the first N attempts, then succeed — the
    /// canonical flaky task.
    FlakyThenOk(u32),
    /// Wedge until the task's wall-clock `timeout` fires: the result is
    /// a timeout kill, with the simulated duration equal to the timeout.
    /// A hang with no timeout configured is reported as killed by the
    /// harness (a real one would stall forever).
    Hang,
    /// The binary could not be started at all.
    SpawnError,
}

/// A deterministic script of task outcomes, keyed by full task key
/// (`task_id#instance`), falling back to bare `task_id`, falling back to
/// the default outcome.
#[derive(Debug)]
pub struct Script {
    outcomes: BTreeMap<String, Outcome>,
    default: Outcome,
    /// Scripted stdout per key (same key/task/default-free precedence as
    /// outcomes), attached to every attempt's result — lets the results
    /// engine's stdout captures run hermetically.
    stdouts: BTreeMap<String, String>,
    /// Simulated per-attempt duration (seconds) reported in results.
    sim_duration: f64,
    /// Per-key simulated durations (same key/task/default precedence as
    /// outcomes) — a heterogeneous synthetic duration landscape for the
    /// packing bench and cost-model tests.
    durations: BTreeMap<String, f64>,
    /// Scripted per-attempt resource telemetry (same key/task precedence;
    /// default all-zero) — hermetic stand-in for the /proc sampler.
    resources: BTreeMap<String, ResourceUsage>,
    /// Logical trace clock advanced by each attempt's simulated
    /// duration — with one worker this yields the exact serial
    /// timeline, making traced replays byte-deterministic.
    clock: Option<Arc<ScriptedClock>>,
    counts: Mutex<BTreeMap<String, u32>>,
    journal: Mutex<Vec<String>>,
}

impl Default for Script {
    fn default() -> Self {
        Script::new()
    }
}

impl Script {
    /// Everything succeeds until told otherwise.
    pub fn new() -> Script {
        Script {
            outcomes: BTreeMap::new(),
            default: Outcome::Succeed,
            stdouts: BTreeMap::new(),
            sim_duration: 0.001,
            durations: BTreeMap::new(),
            resources: BTreeMap::new(),
            clock: None,
            counts: Mutex::new(BTreeMap::new()),
            journal: Mutex::new(Vec::new()),
        }
    }

    /// Script `outcome` for `key` — a full `task_id#instance` key or a
    /// bare `task_id` (applies to every instance of that task).
    pub fn on(mut self, key: impl Into<String>, outcome: Outcome) -> Script {
        self.outcomes.insert(key.into(), outcome);
        self
    }

    /// Outcome for every task the script does not name.
    pub fn default_outcome(mut self, outcome: Outcome) -> Script {
        self.default = outcome;
        self
    }

    /// Scripted stdout for `key` (full `task_id#instance` or bare
    /// `task_id`), reported on every attempt of matching tasks.
    pub fn stdout_on(
        mut self,
        key: impl Into<String>,
        text: impl Into<String>,
    ) -> Script {
        self.stdouts.insert(key.into(), text.into());
        self
    }

    /// Simulated duration reported per attempt (seconds).
    pub fn sim_duration(mut self, secs: f64) -> Script {
        self.sim_duration = secs;
        self
    }

    /// Simulated duration for `key` (full `task_id#instance` or bare
    /// `task_id`), overriding [`Script::sim_duration`] for matching
    /// tasks — still never slept, only reported.
    pub fn duration_on(mut self, key: impl Into<String>, secs: f64) -> Script {
        self.durations.insert(key.into(), secs);
        self
    }

    /// Scripted resource telemetry for `key` (full `task_id#instance`
    /// or bare `task_id`): `cpu_secs`, `max_rss_kb`, `io_read_bytes`,
    /// `io_write_bytes` reported on every matching attempt — the
    /// deterministic stand-in for the runner's /proc sampler.
    pub fn with_resources(
        mut self,
        key: impl Into<String>,
        cpu_secs: f64,
        max_rss_kb: u64,
        io_read_bytes: u64,
        io_write_bytes: u64,
    ) -> Script {
        self.resources.insert(
            key.into(),
            ResourceUsage { cpu_secs, max_rss_kb, io_read_bytes, io_write_bytes },
        );
        self
    }

    /// Advance `clock` by each attempt's simulated duration as it
    /// executes. Share the same clock with the study's trace sink (via
    /// `Study::with_trace_clock`) to get replayable trace timestamps.
    pub fn with_clock(mut self, clock: Arc<ScriptedClock>) -> Script {
        self.clock = Some(clock);
        self
    }

    /// How many times `key` (full `task_id#instance`) reached a worker.
    pub fn executions(&self, key: &str) -> u32 {
        self.counts.lock().unwrap().get(key).copied().unwrap_or(0)
    }

    /// Total executions across every task.
    pub fn total_executions(&self) -> u32 {
        self.counts.lock().unwrap().values().sum()
    }

    /// Task keys in the order workers picked them up.
    pub fn journal(&self) -> Vec<String> {
        self.journal.lock().unwrap().clone()
    }

    fn outcome_for(&self, task: &ConcreteTask, key: &str) -> Outcome {
        self.outcomes
            .get(key)
            .or_else(|| self.outcomes.get(&task.task_id))
            .copied()
            .unwrap_or(self.default)
    }

    fn stdout_for(&self, task: &ConcreteTask, key: &str) -> String {
        self.stdouts
            .get(key)
            .or_else(|| self.stdouts.get(&task.task_id))
            .cloned()
            .unwrap_or_default()
    }

    fn duration_for(&self, task: &ConcreteTask, key: &str) -> f64 {
        self.durations
            .get(key)
            .or_else(|| self.durations.get(&task.task_id))
            .copied()
            .unwrap_or(self.sim_duration)
    }

    fn resources_for(&self, task: &ConcreteTask, key: &str) -> ResourceUsage {
        self.resources
            .get(key)
            .or_else(|| self.resources.get(&task.task_id))
            .copied()
            .unwrap_or_default()
    }

    fn ok_result(&self, duration: f64) -> TaskResult {
        TaskResult {
            ok: true,
            exit_code: 0,
            stdout: String::new(),
            error: None,
            class: None,
            duration,
            worker: String::new(),
            stdout_truncated: false,
            cpu_secs: 0.0,
            max_rss_kb: 0,
            io_read_bytes: 0,
            io_write_bytes: 0,
        }
    }

    fn fail_result(
        &self,
        exit_code: i32,
        class: ErrorClass,
        error: String,
        duration: f64,
    ) -> TaskResult {
        TaskResult {
            ok: false,
            exit_code,
            stdout: String::new(),
            error: Some(error),
            class: Some(class),
            duration,
            worker: String::new(),
            stdout_truncated: false,
            cpu_secs: 0.0,
            max_rss_kb: 0,
            io_read_bytes: 0,
            io_write_bytes: 0,
        }
    }
}

impl TaskExec for Script {
    fn exec(&self, task: &ConcreteTask) -> TaskResult {
        let key = task.key();
        let attempt = {
            let mut counts = self.counts.lock().unwrap();
            let n = counts.entry(key.clone()).or_insert(0);
            *n += 1;
            *n
        };
        self.journal.lock().unwrap().push(key.clone());

        let sim = self.duration_for(task, &key);
        let mut result = match self.outcome_for(task, &key) {
            Outcome::Succeed => self.ok_result(sim),
            Outcome::Fail(code) => self.fail_result(
                code,
                ErrorClass::NonZero,
                format!("scripted failure: exit code {code}"),
                sim,
            ),
            Outcome::FlakyThenOk(n) if attempt <= n => self.fail_result(
                1,
                ErrorClass::NonZero,
                format!("scripted flake {attempt}/{n}: exit code 1"),
                sim,
            ),
            Outcome::FlakyThenOk(_) => self.ok_result(sim),
            Outcome::Hang => match task.timeout {
                Some(limit) => self.fail_result(
                    -1,
                    ErrorClass::Timeout,
                    format!(
                        "timed out after {limit}s (scripted hang: killed + \
                         reaped)"
                    ),
                    limit,
                ),
                None => self.fail_result(
                    -1,
                    ErrorClass::Killed,
                    "scripted hang with no timeout configured — killed by \
                     the test harness"
                        .into(),
                    sim,
                ),
            },
            Outcome::SpawnError => self.fail_result(
                -1,
                ErrorClass::Spawn,
                format!("spawn '{}': scripted spawn failure", task.key()),
                0.0,
            ),
        };
        result.stdout = self.stdout_for(task, &key);
        result.set_resources(self.resources_for(task, &key));
        if let Some(clock) = &self.clock {
            clock.advance(result.duration);
        }
        result
    }
}

/// An [`Executor`] that replays a [`Script`] through the production
/// [`LocalPool`] worker loop — same channels, same fan-out, zero
/// subprocesses, zero sleeps.
pub struct ScriptedExecutor {
    pool: LocalPool,
    script: Arc<Script>,
}

impl ScriptedExecutor {
    /// Executor over `script` with `workers` concurrent workers.
    pub fn new(script: Arc<Script>, workers: usize) -> ScriptedExecutor {
        ScriptedExecutor {
            pool: LocalPool::with_exec(script.clone(), workers),
            script,
        }
    }

    /// The shared script (execution counts + journal).
    pub fn script(&self) -> &Arc<Script> {
        &self.script
    }
}

impl Executor for ScriptedExecutor {
    fn name(&self) -> &'static str {
        "scripted"
    }

    fn workers(&self) -> usize {
        self.pool.workers()
    }

    fn run_all(
        &self,
        ready: Receiver<ConcreteTask>,
        done: Sender<Completion>,
    ) -> Result<()> {
        self.pool.run_all(ready, done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap as Map;
    use std::sync::mpsc;

    fn task(id: &str, instance: u64) -> ConcreteTask {
        ConcreteTask {
            instance,
            task_id: id.into(),
            argv: vec!["work".into()],
            env: Map::new(),
            infiles: vec![],
            outfiles: vec![],
            substitutions: vec![],
            timeout: None,
            retries: 0,
        }
    }

    #[test]
    fn outcome_precedence_key_then_task_then_default() {
        let s = Script::new()
            .default_outcome(Outcome::Fail(9))
            .on("a", Outcome::Succeed)
            .on("a#1", Outcome::Fail(3));
        assert!(s.exec(&task("a", 0)).ok); // task-level
        assert_eq!(s.exec(&task("a", 1)).exit_code, 3); // key-level wins
        let r = s.exec(&task("b", 0)); // default
        assert_eq!(r.exit_code, 9);
        assert_eq!(r.class, Some(ErrorClass::NonZero));
    }

    #[test]
    fn flaky_counts_attempts_per_key() {
        let s = Script::new().on("f", Outcome::FlakyThenOk(2));
        assert!(!s.exec(&task("f", 0)).ok);
        assert!(!s.exec(&task("f", 0)).ok);
        assert!(s.exec(&task("f", 0)).ok);
        // other instances flake independently
        assert!(!s.exec(&task("f", 1)).ok);
        assert_eq!(s.executions("f#0"), 3);
        assert_eq!(s.executions("f#1"), 1);
        assert_eq!(s.total_executions(), 4);
    }

    #[test]
    fn scripted_stdout_attaches_to_results() {
        let s = Script::new()
            .stdout_on("a", "GFLOPS=2.5\n")
            .stdout_on("a#1", "GFLOPS=9.0\n");
        assert_eq!(s.exec(&task("a", 0)).stdout, "GFLOPS=2.5\n");
        assert_eq!(s.exec(&task("a", 1)).stdout, "GFLOPS=9.0\n");
        assert_eq!(s.exec(&task("b", 0)).stdout, "");
    }

    #[test]
    fn duration_precedence_key_then_task_then_sim_default() {
        let s = Script::new()
            .sim_duration(0.5)
            .duration_on("a", 2.0)
            .duration_on("a#1", 8.0);
        assert_eq!(s.exec(&task("a", 0)).duration, 2.0); // task-level
        assert_eq!(s.exec(&task("a", 1)).duration, 8.0); // key-level wins
        assert_eq!(s.exec(&task("b", 0)).duration, 0.5); // default
        // failures report the scripted duration too
        let s = Script::new()
            .default_outcome(Outcome::Fail(2))
            .duration_on("c", 3.25);
        assert_eq!(s.exec(&task("c", 0)).duration, 3.25);
    }

    #[test]
    fn resource_precedence_key_then_task_then_zero() {
        let s = Script::new()
            .with_resources("a", 1.5, 4096, 100, 200)
            .with_resources("a#1", 9.0, 65536, 7, 8);
        let r = s.exec(&task("a", 0)); // task-level
        assert_eq!(r.cpu_secs, 1.5);
        assert_eq!(r.max_rss_kb, 4096);
        assert_eq!((r.io_read_bytes, r.io_write_bytes), (100, 200));
        let r = s.exec(&task("a", 1)); // key-level wins
        assert_eq!(r.cpu_secs, 9.0);
        assert_eq!(r.max_rss_kb, 65536);
        let r = s.exec(&task("b", 0)); // unscripted → zeros
        assert_eq!(r.cpu_secs, 0.0);
        assert_eq!(r.max_rss_kb, 0);
        // failures carry scripted resources too (a task can OOM-ish
        // *and* fail)
        let s = Script::new()
            .default_outcome(Outcome::Fail(2))
            .with_resources("c", 0.5, 123, 0, 0);
        assert_eq!(s.exec(&task("c", 0)).max_rss_kb, 123);
    }

    #[test]
    fn script_advances_its_trace_clock_by_simulated_durations() {
        let clock = Arc::new(ScriptedClock::new());
        let s = Script::new()
            .duration_on("a", 2.0)
            .duration_on("b", 0.5)
            .with_clock(clock.clone());
        s.exec(&task("a", 0));
        assert_eq!(clock.now(), 2.0);
        s.exec(&task("b", 0));
        assert_eq!(clock.now(), 2.5);
    }

    #[test]
    fn hang_honors_simulated_timeout() {
        let s = Script::new().on("h", Outcome::Hang);
        let mut t = task("h", 0);
        t.timeout = Some(2.5);
        let r = s.exec(&t);
        assert!(!r.ok);
        assert_eq!(r.class, Some(ErrorClass::Timeout));
        assert_eq!(r.duration, 2.5);
        // no timeout: killed by the harness instead of stalling the test
        let r = s.exec(&task("h", 1));
        assert_eq!(r.class, Some(ErrorClass::Killed));
    }

    #[test]
    fn scripted_executor_drains_all_tasks_in_parallel() {
        let script = Arc::new(Script::new());
        let exec = ScriptedExecutor::new(script.clone(), 4);
        assert_eq!(exec.name(), "scripted");
        assert_eq!(exec.workers(), 4);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        for i in 0..20 {
            tx.send(task("t", i)).unwrap();
        }
        drop(tx);
        exec.run_all(rx, dtx).unwrap();
        let results: Vec<Completion> = drx.into_iter().collect();
        assert_eq!(results.len(), 20);
        assert!(results.iter().all(|(_, r)| r.ok));
        assert_eq!(script.total_executions(), 20);
        let workers: std::collections::BTreeSet<&str> =
            results.iter().map(|(_, r)| r.worker.as_str()).collect();
        assert!(workers.len() > 1, "{workers:?}");
    }

    #[test]
    fn single_worker_journal_preserves_send_order() {
        let script = Arc::new(Script::new());
        let exec = ScriptedExecutor::new(script.clone(), 1);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        for i in 0..6 {
            tx.send(task("t", i)).unwrap();
        }
        drop(tx);
        exec.run_all(rx, dtx).unwrap();
        drop(drx);
        let expect: Vec<String> = (0..6).map(|i| format!("t#{i}")).collect();
        assert_eq!(script.journal(), expect);
    }
}
