//! SSH-mode execution (§4.3: unmanaged clusters "mostly single-user with
//! an SSH setup").
//!
//! Topology: one worker *daemon* per host entry, reached over a TCP
//! socket with a length-prefixed JSON protocol; the pool holds one
//! connection per daemon and streams tasks over it. On a real unmanaged
//! cluster the daemons are started via `ssh host papas worker --bind
//! 0.0.0.0:PORT`; in this testbed they are started on localhost (the
//! `hosts` keyword accepts `host:port` entries for externally-started
//! daemons — `papas worker` is the CLI entry point — and an empty list
//! auto-starts in-process daemons on ephemeral ports, preserving the
//! exact wire protocol without a second machine).
//!
//! Wire protocol (all frames are `u32 BE length ++ JSON bytes`):
//!
//! ```text
//! pool → daemon   {"op": "run", "task": {...ConcreteTask...}}
//! daemon → pool   {"op": "done", "result": {...TaskResult...}}
//! pool → daemon   {"op": "shutdown"}
//! ```

use super::runner::{TaskResult, TaskRunner};
use super::{Completion, Executor};
use crate::json::{self, Json};
use crate::util::error::{Error, Result};
use crate::workflow::ConcreteTask;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

// ---------------------------------------------------------------- frames

/// Write one length-prefixed JSON frame.
pub fn write_frame(stream: &mut TcpStream, j: &Json) -> Result<()> {
    let body = json::to_string(j).into_bytes();
    let len = (body.len() as u32).to_be_bytes();
    stream.write_all(&len)?;
    stream.write_all(&body)?;
    Ok(())
}

/// Read one length-prefixed JSON frame (None on clean EOF).
pub fn read_frame(stream: &mut TcpStream) -> Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > 64 << 20 {
        return Err(Error::Exec(format!("oversized frame ({len} bytes)")));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    let text = String::from_utf8(body)
        .map_err(|_| Error::Exec("non-UTF-8 frame".into()))?;
    Ok(Some(json::parse(&text)?))
}

// ------------------------------------------------------- (de)serializers

fn result_to_json(r: &TaskResult) -> Json {
    Json::obj([
        ("ok".to_string(), Json::from(r.ok)),
        ("exit_code".to_string(), Json::from(r.exit_code as i64)),
        ("stdout".to_string(), Json::from(r.stdout.as_str())),
        (
            "error".to_string(),
            r.error.as_deref().map(Json::from).unwrap_or(Json::Null),
        ),
        (
            "class".to_string(),
            r.class.map(|c| Json::from(c.label())).unwrap_or(Json::Null),
        ),
        ("duration".to_string(), Json::Num(r.duration)),
        ("worker".to_string(), Json::from(r.worker.as_str())),
        ("stdout_truncated".to_string(), Json::from(r.stdout_truncated)),
        ("cpu_secs".to_string(), Json::Num(r.cpu_secs)),
        ("max_rss_kb".to_string(), Json::from(r.max_rss_kb as i64)),
        ("io_read_bytes".to_string(), Json::from(r.io_read_bytes as i64)),
        ("io_write_bytes".to_string(), Json::from(r.io_write_bytes as i64)),
    ])
}

fn result_from_json(j: &Json) -> Result<TaskResult> {
    Ok(TaskResult {
        ok: j.expect("ok")?.as_bool().unwrap_or(false),
        exit_code: j.expect_i64("exit_code")? as i32,
        stdout: j.expect_str("stdout")?.to_string(),
        error: j.get("error").and_then(Json::as_str).map(str::to_string),
        class: j
            .get("class")
            .and_then(Json::as_str)
            .and_then(crate::exec::ErrorClass::parse),
        duration: j.expect("duration")?.as_f64().unwrap_or(0.0),
        worker: j.expect_str("worker")?.to_string(),
        // Tolerant defaults: frames from pre-upgrade daemons lack these.
        stdout_truncated: j
            .get("stdout_truncated")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        cpu_secs: j.get("cpu_secs").and_then(Json::as_f64).unwrap_or(0.0),
        max_rss_kb: j.get("max_rss_kb").and_then(Json::as_i64).unwrap_or(0)
            as u64,
        io_read_bytes: j
            .get("io_read_bytes")
            .and_then(Json::as_i64)
            .unwrap_or(0) as u64,
        io_write_bytes: j
            .get("io_write_bytes")
            .and_then(Json::as_i64)
            .unwrap_or(0) as u64,
    })
}

// ----------------------------------------------------------------- daemon

/// A worker daemon bound to an address. `papas worker --bind ADDR` wraps
/// this; tests and the auto-start path call [`WorkerDaemon::spawn`].
pub struct WorkerDaemon {
    /// The bound address (useful with `--bind 127.0.0.1:0`).
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    runner: Arc<TaskRunner>,
}

impl WorkerDaemon {
    /// Bind a daemon (does not serve yet).
    pub fn bind(addr: &str, runner: Arc<TaskRunner>) -> Result<WorkerDaemon> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Exec(format!("bind {addr}: {e}")))?;
        let addr = listener.local_addr()?;
        Ok(WorkerDaemon { addr, listener, runner })
    }

    /// Serve connections until a `shutdown` frame arrives (the CLI
    /// foreground mode). Each connection is a sequential task stream.
    pub fn serve(self) -> Result<()> {
        for conn in self.listener.incoming() {
            let mut stream = conn?;
            if !Self::serve_connection(&mut stream, &self.runner)? {
                break; // shutdown requested
            }
        }
        Ok(())
    }

    /// Bind on an ephemeral localhost port and serve on a background
    /// thread. Returns the address to connect to.
    pub fn spawn(runner: Arc<TaskRunner>) -> Result<std::net::SocketAddr> {
        let daemon = WorkerDaemon::bind("127.0.0.1:0", runner)?;
        let addr = daemon.addr;
        std::thread::spawn(move || {
            let _ = daemon.serve();
        });
        Ok(addr)
    }

    /// Handle one connection; returns false when shutdown was requested.
    fn serve_connection(
        stream: &mut TcpStream,
        runner: &Arc<TaskRunner>,
    ) -> Result<bool> {
        // Frames are small request/response pairs: Nagle + delayed-ACK
        // stalls each task ~40ms without this (EXPERIMENTS.md §Perf).
        let _ = stream.set_nodelay(true);
        while let Some(frame) = read_frame(stream)? {
            match frame.get("op").and_then(Json::as_str) {
                Some("run") => {
                    let task = ConcreteTask::from_json(frame.expect("task")?)?;
                    let result = runner.run(&task);
                    write_frame(
                        stream,
                        &Json::obj([
                            ("op".to_string(), Json::from("done")),
                            ("result".to_string(), result_to_json(&result)),
                        ]),
                    )?;
                }
                Some("ping") => {
                    write_frame(stream, &Json::obj([("op".to_string(), Json::from("pong"))]))?;
                }
                Some("shutdown") => return Ok(false),
                other => {
                    return Err(Error::Exec(format!(
                        "unknown op {other:?} on worker wire"
                    )))
                }
            }
        }
        Ok(true)
    }
}

// ------------------------------------------------------------------- pool

/// The SSH-mode executor: a connection per host, tasks streamed to idle
/// hosts from the shared ready channel.
pub struct SshPool {
    addrs: Vec<String>,
}

impl SshPool {
    /// Connect to externally-started daemons (`host:port` entries from
    /// the WDL `hosts` keyword).
    pub fn connect(addrs: Vec<String>) -> Result<SshPool> {
        if addrs.is_empty() {
            return Err(Error::Exec("ssh pool needs at least one host".into()));
        }
        Ok(SshPool { addrs })
    }

    /// Auto-start `n` in-process localhost daemons (the empty-`hosts`
    /// default) sharing `runner`.
    pub fn spawn_local(runner: Arc<TaskRunner>, n: usize) -> Result<SshPool> {
        let mut addrs = Vec::new();
        for _ in 0..n.max(1) {
            addrs.push(WorkerDaemon::spawn(runner.clone())?.to_string());
        }
        Ok(SshPool { addrs })
    }

    /// The daemon addresses in use.
    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }
}

impl Executor for SshPool {
    fn name(&self) -> &'static str {
        "ssh"
    }

    fn workers(&self) -> usize {
        self.addrs.len()
    }

    fn run_all(
        &self,
        ready: Receiver<ConcreteTask>,
        done: Sender<Completion>,
    ) -> Result<()> {
        let shared = Arc::new(Mutex::new(ready));
        std::thread::scope(|s| -> Result<()> {
            for (i, addr) in self.addrs.iter().enumerate() {
                let mut stream = TcpStream::connect(addr)
                    .map_err(|e| Error::Exec(format!("connect {addr}: {e}")))?;
                // Small framed RPCs: disable Nagle (see §Perf).
                let _ = stream.set_nodelay(true);
                let shared = shared.clone();
                let done = done.clone();
                let host_label = format!("ssh-{i}@{addr}");
                s.spawn(move || {
                    loop {
                        let task = {
                            let rx = shared.lock().unwrap();
                            rx.recv()
                        };
                        let Ok(task) = task else { break };
                        let outcome = (|| -> Result<TaskResult> {
                            write_frame(
                                &mut stream,
                                &Json::obj([
                                    ("op".to_string(), Json::from("run")),
                                    ("task".to_string(), task.to_json()),
                                ]),
                            )?;
                            let reply = read_frame(&mut stream)?.ok_or_else(|| {
                                Error::Exec(format!("{host_label}: connection closed"))
                            })?;
                            result_from_json(reply.expect("result")?)
                        })();
                        let mut result = outcome.unwrap_or_else(|e| TaskResult {
                            ok: false,
                            exit_code: -1,
                            stdout: String::new(),
                            error: Some(format!("wire error: {e}")),
                            class: Some(crate::exec::ErrorClass::Spawn),
                            duration: 0.0,
                            worker: String::new(),
                            stdout_truncated: false,
                            cpu_secs: 0.0,
                            max_rss_kb: 0,
                            io_read_bytes: 0,
                            io_write_bytes: 0,
                        });
                        result.worker = host_label.clone();
                        if done.send((task, result)).is_err() {
                            break;
                        }
                    }
                    let _ = write_frame(
                        &mut stream,
                        &Json::obj([("op".to_string(), Json::from("shutdown"))]),
                    );
                });
            }
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::runner::RunConfig;
    use crate::tasks::Builtins;
    use std::collections::BTreeMap;
    use std::sync::mpsc;

    fn runner() -> Arc<TaskRunner> {
        let root = std::env::temp_dir().join("papas_ssh");
        std::fs::create_dir_all(&root).unwrap();
        Arc::new(TaskRunner::new(
            Arc::new(Builtins::without_runtime()),
            RunConfig {
                work_root: root.join("work"),
                input_root: root.join("inputs"),
            },
        ))
    }

    fn sleep_task(i: u64) -> ConcreteTask {
        ConcreteTask {
            instance: i,
            task_id: "t".into(),
            argv: vec!["sleep-ms".into(), "1".into()],
            env: BTreeMap::new(),
            infiles: vec![],
            outfiles: vec![],
            substitutions: vec![],
            timeout: None,
            retries: 0,
        }
    }

    #[test]
    fn daemon_ping_pong() {
        let addr = WorkerDaemon::spawn(runner()).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        write_frame(&mut s, &Json::obj([("op".to_string(), Json::from("ping"))])).unwrap();
        let reply = read_frame(&mut s).unwrap().unwrap();
        assert_eq!(reply.get("op").and_then(Json::as_str), Some("pong"));
    }

    #[test]
    fn pool_runs_tasks_over_wire() {
        let pool = SshPool::spawn_local(runner(), 3).unwrap();
        assert_eq!(pool.workers(), 3);
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = mpsc::channel();
        for i in 0..12 {
            tx.send(sleep_task(i)).unwrap();
        }
        drop(tx);
        pool.run_all(rx, dtx).unwrap();
        let results: Vec<Completion> = drx.into_iter().collect();
        assert_eq!(results.len(), 12);
        assert!(results.iter().all(|(_, r)| r.ok), "{results:?}");
        let hosts: std::collections::BTreeSet<&str> =
            results.iter().map(|(_, r)| r.worker.as_str()).collect();
        assert_eq!(hosts.len(), 3, "{hosts:?}");
    }

    #[test]
    fn wire_failure_is_reported_as_task_failure() {
        // daemon for one real task, then kill by connecting to a port
        // nobody listens on
        let pool = SshPool::connect(vec!["127.0.0.1:1".into()]).unwrap();
        let (tx, rx) = mpsc::channel::<ConcreteTask>();
        let (dtx, _drx) = mpsc::channel();
        drop(tx);
        // connect fails fast → run_all errors (connection refused)
        assert!(pool.run_all(rx, dtx).is_err());
    }

    #[test]
    fn frame_round_trip_large() {
        let addr = WorkerDaemon::spawn(runner()).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        // a run frame with a large env exercises framing
        let mut task = sleep_task(0);
        for i in 0..200 {
            task.env.insert(format!("VAR_{i}"), "x".repeat(100));
        }
        write_frame(
            &mut s,
            &Json::obj([
                ("op".to_string(), Json::from("run")),
                ("task".to_string(), task.to_json()),
            ]),
        )
        .unwrap();
        let reply = read_frame(&mut s).unwrap().unwrap();
        let result = result_from_json(reply.expect("result").unwrap()).unwrap();
        assert!(result.ok, "{result:?}");
    }

    #[test]
    fn empty_hosts_rejected() {
        assert!(SshPool::connect(vec![]).is_err());
    }
}
