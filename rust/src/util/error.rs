//! The crate-wide error type.
//!
//! One enum, one variant per subsystem, so call sites can match on the
//! failing layer (parse vs. validation vs. execution vs. runtime) — the
//! distinction the CLI uses for exit codes and the scheduler uses to
//! decide retry vs. abort.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by the PaPaS framework, tagged by subsystem.
/// (`Display`/`Error`/`From` are hand-implemented — no proc-macro crates
/// are available offline.)
#[derive(Debug)]
pub enum Error {
    /// Lexical / syntactic error in a parameter file (YAML/JSON/INI).
    Parse { location: Location, message: String },

    /// Structurally valid document that violates the WDL specification.
    Wdl(String),

    /// `${...}` interpolation failure (unknown key, cycle, bad scope).
    Interp(String),

    /// Parameter-space error (empty space, fixed-clause arity mismatch...).
    Params(String),

    /// Workflow DAG error (cycle, unknown dependency, duplicate task).
    Workflow(String),

    /// Task execution failure (spawn error, non-zero exit, staging error).
    Exec(String),

    /// Cluster engine error (unknown job, bad directive, sim invariant).
    Cluster(String),

    /// PJRT runtime error (artifact missing, compile/execute failure).
    Runtime(String),

    /// Checkpoint / file-database error.
    Store(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse { location, message } => {
                write!(f, "parse error at {location}: {message}")
            }
            Error::Wdl(m) => write!(f, "invalid workflow description: {m}"),
            Error::Interp(m) => write!(f, "interpolation error: {m}"),
            Error::Params(m) => write!(f, "parameter space error: {m}"),
            Error::Workflow(m) => write!(f, "workflow error: {m}"),
            Error::Exec(m) => write!(f, "execution error: {m}"),
            Error::Cluster(m) => write!(f, "cluster error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Store(m) => write!(f, "state store error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for parse errors.
    pub fn parse(location: Location, message: impl Into<String>) -> Self {
        Error::Parse { location, message: message.into() }
    }

    /// Stable subsystem tag (used by the CLI for exit codes and by tests).
    pub fn subsystem(&self) -> &'static str {
        match self {
            Error::Parse { .. } => "parse",
            Error::Wdl(_) => "wdl",
            Error::Interp(_) => "interp",
            Error::Params(_) => "params",
            Error::Workflow(_) => "workflow",
            Error::Exec(_) => "exec",
            Error::Cluster(_) => "cluster",
            Error::Runtime(_) => "runtime",
            Error::Store(_) => "store",
            Error::Io(_) => "io",
        }
    }

    /// Whether the scheduler may retry the operation (transient failures).
    pub fn retryable(&self) -> bool {
        matches!(self, Error::Exec(_) | Error::Io(_))
    }
}

/// A position in a source document, for parser diagnostics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Location {
    /// 1-based line number.
    pub line: usize,
    /// 1-based column number.
    pub col: usize,
}

impl Location {
    /// Location at the start of a document.
    pub const START: Location = Location { line: 1, col: 1 };

    /// New location.
    pub fn new(line: usize, col: usize) -> Self {
        Location { line, col }
    }
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}, col {}", self.line, self.col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_location() {
        let e = Error::parse(Location::new(3, 7), "unexpected ':'");
        let s = e.to_string();
        assert!(s.contains("line 3"), "{s}");
        assert!(s.contains("col 7"), "{s}");
        assert!(s.contains("unexpected ':'"), "{s}");
    }

    #[test]
    fn subsystem_tags_are_stable() {
        assert_eq!(Error::Wdl("x".into()).subsystem(), "wdl");
        assert_eq!(Error::Runtime("x".into()).subsystem(), "runtime");
        assert_eq!(
            Error::parse(Location::START, "x").subsystem(),
            "parse"
        );
    }

    #[test]
    fn retryability() {
        assert!(Error::Exec("spawn failed".into()).retryable());
        assert!(!Error::Wdl("bad keyword".into()).retryable());
    }
}
