//! Small string helpers shared by the parsers and interpolation engine.

/// True if `s` is a valid WDL identifier: alphanumeric plus `_`, `-`, `.`
/// (the paper allows "any alphanumeric character" for keywords; we accept
/// the separators its own examples use, e.g. `OMP_NUM_THREADS`).
pub fn is_identifier(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.'))
}

/// Split `s` on `sep` at the top level only — separators inside single or
/// double quotes or inside `${...}` are not split points.
pub fn split_top_level(s: &str, sep: char) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut chars = s.chars().peekable();
    let mut in_single = false;
    let mut in_double = false;
    let mut brace_depth = 0usize;
    while let Some(c) = chars.next() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '$' if !in_single && chars.peek() == Some(&'{') => {
                cur.push(c);
                cur.push(chars.next().unwrap());
                brace_depth += 1;
                continue;
            }
            '}' if brace_depth > 0 => brace_depth -= 1,
            c if c == sep && !in_single && !in_double && brace_depth == 0 => {
                parts.push(cur.clone());
                cur.clear();
                continue;
            }
            _ => {}
        }
        cur.push(c);
    }
    parts.push(cur);
    parts
}

/// Strip one layer of matching single or double quotes.
pub fn unquote(s: &str) -> &str {
    let b = s.as_bytes();
    if b.len() >= 2
        && ((b[0] == b'"' && b[b.len() - 1] == b'"')
            || (b[0] == b'\'' && b[b.len() - 1] == b'\''))
    {
        &s[1..s.len() - 1]
    } else {
        s
    }
}

/// Shell-style tokenization of a command line: whitespace-separated with
/// single/double-quote grouping. Used by the shell task executor so
/// commands run without invoking /bin/sh (portability + no injection).
pub fn shell_split(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut started = false;
    let mut in_single = false;
    let mut in_double = false;
    for c in s.chars() {
        match c {
            '\'' if !in_double => {
                in_single = !in_single;
                started = true;
            }
            '"' if !in_single => {
                in_double = !in_double;
                started = true;
            }
            c if c.is_whitespace() && !in_single && !in_double => {
                if started {
                    out.push(std::mem::take(&mut cur));
                    started = false;
                }
            }
            c => {
                cur.push(c);
                started = true;
            }
        }
    }
    if started {
        out.push(cur);
    }
    out
}

/// Format a f64 the way the WDL writes values: integers print without a
/// trailing `.0` (so interpolated file names look like `result_16N_1T.txt`).
pub fn fmt_number(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// RFC-4180 CSV field quoting: fields containing a comma, double quote,
/// or newline are wrapped in double quotes with inner quotes doubled;
/// everything else passes through. Used by the aggregator's provenance
/// columns and the query layer's CSV output so parameter values
/// containing commas cannot corrupt row structure.
pub fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identifiers() {
        assert!(is_identifier("OMP_NUM_THREADS"));
        assert!(is_identifier("matmul-omp.v2"));
        assert!(!is_identifier(""));
        assert!(!is_identifier("a b"));
        assert!(!is_identifier("x:y"));
    }

    #[test]
    fn split_respects_quotes_and_braces() {
        assert_eq!(
            split_top_level("a:b:c", ':'),
            vec!["a", "b", "c"]
        );
        assert_eq!(
            split_top_level("cmd '${a:b}':rest", ':'),
            vec!["cmd '${a:b}'", "rest"]
        );
        assert_eq!(
            split_top_level("${x:y}:z", ':'),
            vec!["${x:y}", "z"]
        );
    }

    #[test]
    fn unquote_strips_one_layer() {
        assert_eq!(unquote("\"hi\""), "hi");
        assert_eq!(unquote("'hi'"), "hi");
        assert_eq!(unquote("hi"), "hi");
        assert_eq!(unquote("\"'hi'\""), "'hi'");
    }

    #[test]
    fn shell_split_groups_quotes() {
        assert_eq!(
            shell_split("matmul 16 'out file.txt' --v=\"a b\""),
            vec!["matmul", "16", "out file.txt", "--v=a b"]
        );
        assert_eq!(shell_split("  "), Vec::<String>::new());
        assert_eq!(shell_split("''"), vec![""]);
    }

    #[test]
    fn number_formatting() {
        assert_eq!(fmt_number(16.0), "16");
        assert_eq!(fmt_number(0.5), "0.5");
        assert_eq!(fmt_number(-3.0), "-3");
    }

    #[test]
    fn csv_field_quotes_only_when_needed() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_field("two\nlines"), "\"two\nlines\"");
        assert_eq!(csv_field(""), "");
    }
}
