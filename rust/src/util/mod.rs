//! Shared substrates: error type, PRNG, timing/stats, string helpers,
//! and the in-tree property-testing harness.
//!
//! These exist because the offline crate registry carries neither `rand`,
//! `serde`, `criterion`, nor `proptest` — every general-purpose facility
//! the framework needs is implemented here from scratch (DESIGN.md §5).

pub mod error;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod strings;

pub use error::{Error, Result};
pub use rng::Rng;
pub use stats::Summary;
