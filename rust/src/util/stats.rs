//! Timing and summary statistics (criterion substitute).
//!
//! Used by the task profiler (§4.2 "a task profiler measures each task's
//! runtime"), the bench harness, and the perf pass.

use std::time::{Duration, Instant};

/// Summary statistics over a sample of durations/values (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (0 for n < 2).
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Median (p50).
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Compute a summary from raw samples. Empty input yields all-zeros.
    pub fn from_samples(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p95: 0.0, max: 0.0 };
        }
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: percentile(&xs, 0.50),
            p95: percentile(&xs, 0.95),
            max: xs[n - 1],
        }
    }

    /// Compute a summary from durations.
    pub fn from_durations(ds: &[Duration]) -> Summary {
        let secs: Vec<f64> = ds.iter().map(|d| d.as_secs_f64()).collect();
        Summary::from_samples(&secs)
    }
}

/// Linear-interpolated percentile of a pre-sorted slice; q in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// A running stopwatch for task profiling.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds since start.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_samples() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        // sample std of 1..5 = sqrt(2.5)
        assert!((s.std - 2.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(Summary::from_samples(&[]).n, 0);
        let s = Summary::from_samples(&[7.5]);
        assert_eq!(s.p95, 7.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.95) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn timed_measures_something() {
        let (v, d) = timed(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(d >= Duration::from_millis(4));
    }
}
