//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! No `rand` crate offline; this is the single randomness source for the
//! whole framework — parameter-space `sampling`, the cluster simulator's
//! tenancy-delay draws, workload generators, and the property-test
//! harness. Determinism matters: every figure reproduction is seeded so
//! reruns produce identical traces.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm),
/// seeded from a single u64 via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid:
    /// SplitMix64 expansion guarantees a non-zero internal state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Derive an independent stream for a subcomponent (cheap fork).
    /// Mirrors `jax.random.fold_in` usage on the Python side.
    pub fn fold_in(&self, data: u64) -> Rng {
        Rng::new(self.s[0] ^ data.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits of the raw draw.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    /// Lemire-style rejection for unbiased bounded draws.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let m = (r as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "empty range");
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Exponentially-distributed f64 with the given mean (cluster-sim
    /// inter-arrival and service-delay draws).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Normal(mu, sigma) via Box–Muller (sim jitter on task durations).
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        let z = (-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f64::consts::PI * u2).cos();
        mu + sigma * z
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (parameter-space sampling).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_bounded_and_hits_all_values() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut r = Rng::new(0);
        let v: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert!(v.iter().any(|&x| x != 0));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(99);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        for w in s.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn fold_in_decorrelates() {
        let base = Rng::new(42);
        let mut a = base.fold_in(1);
        let mut b = base.fold_in(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
