//! In-tree property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a `Gen` (seeded random source with value
//! generators). `check` runs it for N seeded cases; on failure it retries
//! the same seed with a smaller size budget — a cheap form of shrinking —
//! and reports the seed so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath; the same property
//! // executes for real in this module's unit tests)
//! use papas::util::proptest::{check, Gen};
//! check("reverse twice is identity", 256, |g| {
//!     let xs = g.vec(0..=64, |g| g.i64(-100..=100));
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use super::rng::Rng;
use std::ops::RangeInclusive;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// A seeded generator handed to property closures.
pub struct Gen {
    rng: Rng,
    /// Size budget: generators scale collection sizes by this (0.0–1.0).
    size: f64,
}

impl Gen {
    /// New generator for a case seed.
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// Raw access to the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in an inclusive range.
    pub fn i64(&mut self, r: RangeInclusive<i64>) -> i64 {
        self.rng.range_inclusive(*r.start(), *r.end())
    }

    /// usize in an inclusive range, scaled down by the size budget when
    /// shrinking (never below the range start).
    pub fn usize(&mut self, r: RangeInclusive<usize>) -> usize {
        let lo = *r.start();
        let hi = *r.end();
        let scaled_hi = lo + (((hi - lo) as f64) * self.size) as usize;
        self.rng.range_inclusive(lo as i64, scaled_hi.max(lo) as i64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64_unit(&mut self) -> f64 {
        self.rng.uniform()
    }

    /// Boolean with probability p of true.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.uniform() < p
    }

    /// One element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Vec with length drawn from `len`, elements from `f`.
    pub fn vec<T>(
        &mut self,
        len: RangeInclusive<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Lower-case ASCII identifier of length 1..=12.
    pub fn ident(&mut self) -> String {
        let n = self.usize(1..=12);
        (0..n)
            .map(|_| (b'a' + self.rng.below(26) as u8) as char)
            .collect()
    }
}

/// Run `prop` for `cases` seeded cases. Panics (failing the enclosing
/// test) with the case seed on the first failure, after attempting a
/// smaller-size replay of the same seed to report the simplest variant.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen)) {
    for case in 0..cases {
        let seed = 0x5EED_0000_0000_0000 ^ case;
        let failed = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        }))
        .is_err();
        if failed {
            // Cheap shrink: replay the same seed with smaller size budgets
            // and report the smallest budget that still fails.
            let mut min_size = 1.0;
            for &size in &[0.0, 0.1, 0.25, 0.5] {
                let fails = catch_unwind(AssertUnwindSafe(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                }))
                .is_err();
                if fails {
                    min_size = size;
                    break;
                }
            }
            panic!(
                "property '{name}' failed: case={case} seed={seed:#x} \
                 (replay with Gen::new({seed:#x}, {min_size}))"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", 64, |g| {
            let a = g.i64(-1000..=1000);
            let b = g.i64(-1000..=1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 8, |g| {
            let v = g.i64(0..=10);
            assert!(v > 100, "v={v}");
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 128, |g| {
            let n = g.usize(2..=9);
            assert!((2..=9).contains(&n));
            let v = g.vec(0..=5, |g| g.i64(0..=1));
            assert!(v.len() <= 5);
            let id = g.ident();
            assert!(!id.is_empty() && id.len() <= 12);
        });
    }
}
