//! # PaPaS — Parallel Parameter Studies
//!
//! A reproduction of *"PaPaS: A Portable, Lightweight, and Generic
//! Framework for Parallel Parameter Studies"* (Ponce et al., PEARC '18)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the PaPaS coordinator: workflow-description-
//!   language parsers (YAML / JSON / INI), the parameter combinatorial
//!   engine (§5.1), the workflow DAG engine (§4.2), executors (local
//!   thread pool, MPI-style dispatcher, SSH-style TCP workers), the
//!   cluster engine with a PBS-like batch interface and a discrete-event
//!   cluster simulator (§4.3), provenance + checkpoint/restart (§4.1),
//!   and the visualization engine (§4.4).
//! * **L2/L1 (python/, build-time only)** — the swept workloads (C.
//!   difficile ward ABM, tiled matmul) as JAX programs calling Pallas
//!   kernels, AOT-lowered to HLO text artifacts.
//! * **runtime** — loads `artifacts/*.hlo.txt` via the PJRT C API and
//!   executes them on the Rust request path; Python never runs at
//!   request time.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use papas::study::Study;
//! let study = Study::from_file("studies/matmul_omp.yaml").unwrap();
//! let report = study.run_local(2).unwrap();
//! println!("{} workflow instances done", report.completed);
//! ```

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod exec;
pub mod ini;
pub mod json;
pub mod obs;
pub mod params;
pub mod results;
pub mod runtime;
pub mod search;
pub mod study;
pub mod synth;
pub mod tasks;
pub mod util;
pub mod viz;
pub mod wdl;
pub mod workflow;
pub mod yamlite;
