//! The `papas` command-line interface (hand-rolled; clap is unavailable
//! offline).
//!
//! ```text
//! papas run STUDY.yaml [--workers N] [--mode local|mpi|ssh]
//!                      [--nnodes N] [--ppnode P] [--artifacts DIR]
//!                      [--db DIR] [--fresh]
//! papas validate STUDY.yaml [...overlays]
//! papas combos STUDY.yaml [--limit N]      # Figure 6: enumerate instances
//! papas viz STUDY.yaml [--dot]
//! papas resume STUDY.yaml [...run flags]   # alias of run (checkpoint-aware)
//! papas worker --bind ADDR [--artifacts DIR]
//! papas qsim --jobs N --regime R [--nodes N] [--duration S] [--seed S]
//! papas harvest STUDY.yaml                 # backfill typed results
//! papas query STUDY.yaml [--where ...] [--by ...]   # query results
//! papas report STUDY.yaml --metric M --by AXIS      # perf summary
//! papas search STUDY.yaml [--rounds N] [--budget K] # adaptive search
//! papas synth [--seed S] [--count N] [--replay]     # synthetic studies
//! papas trace STUDY [--run ID] [--export chrome|csv|summary]
//! papas watch STUDY [--interval S] [--once]         # live trace tail
//! papas doctor STUDY [--run ID] [--format text|json] [--mem-budget KB]
//! papas status STUDY [--serve ADDR [--once]]        # /metrics + /status
//! ```

pub mod args;
pub mod commands;

pub use args::{Args, ParsedCommand};

use crate::util::error::Result;

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn main_with(argv: &[String]) -> i32 {
    match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("papas: error [{}]: {e}", e.subsystem());
            1
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    match Args::parse(argv)? {
        ParsedCommand::Run(a) => commands::cmd_run(&a, false),
        ParsedCommand::Resume(a) => commands::cmd_run(&a, true),
        ParsedCommand::Validate(a) => commands::cmd_validate(&a),
        ParsedCommand::Combos(a) => commands::cmd_combos(&a),
        ParsedCommand::Instance(a) => commands::cmd_instance(&a),
        ParsedCommand::Viz(a) => commands::cmd_viz(&a),
        ParsedCommand::Worker(a) => commands::cmd_worker(&a),
        ParsedCommand::Qsim(a) => commands::cmd_qsim(&a),
        ParsedCommand::Aggregate(a) => commands::cmd_aggregate(&a),
        ParsedCommand::Dax(a) => commands::cmd_dax(&a),
        ParsedCommand::Status(a) => commands::cmd_status(&a),
        ParsedCommand::Harvest(a) => commands::cmd_harvest(&a),
        ParsedCommand::Query(a) => commands::cmd_query(&a),
        ParsedCommand::Report(a) => commands::cmd_report(&a),
        ParsedCommand::Search(a) => commands::cmd_search(&a),
        ParsedCommand::Synth(a) => commands::cmd_synth(&a),
        ParsedCommand::Doctor(a) => commands::cmd_doctor(&a),
        ParsedCommand::Trace(a) => commands::cmd_trace(&a),
        ParsedCommand::Watch(a) => commands::cmd_watch(&a),
        ParsedCommand::Help => {
            println!("{}", commands::USAGE);
            Ok(())
        }
    }
}
