//! Argument parsing for the `papas` CLI (no clap offline).

use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed flags: positional args + `--key value` / `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

/// The recognized subcommands.
#[derive(Debug)]
pub enum ParsedCommand {
    /// `papas run ...`
    Run(Args),
    /// `papas resume ...`
    Resume(Args),
    /// `papas validate ...`
    Validate(Args),
    /// `papas combos ...`
    Combos(Args),
    /// `papas instance STUDY.yaml IDX` (materialize exactly one instance)
    Instance(Args),
    /// `papas viz ...`
    Viz(Args),
    /// `papas worker ...`
    Worker(Args),
    /// `papas qsim ...`
    Qsim(Args),
    /// `papas aggregate ...` (§9 extension: merge instance outputs)
    Aggregate(Args),
    /// `papas dax ...` (§9 extension: Pegasus DAX export)
    Dax(Args),
    /// `papas status ...` (file-database monitoring view)
    Status(Args),
    /// `papas harvest ...` (backfill the typed result store post-hoc)
    Harvest(Args),
    /// `papas query ...` (filter/group/aggregate captured results)
    Query(Args),
    /// `papas report ...` (per-axis performance summary with speedup)
    Report(Args),
    /// `papas search ...` (adaptive round-based study driver)
    Search(Args),
    /// `papas synth ...` (seeded synthetic-study generator / replayer)
    Synth(Args),
    /// `papas doctor ...` (critical-path / bottleneck diagnosis)
    Doctor(Args),
    /// `papas trace ...` (inspect/export a run's trace journal)
    Trace(Args),
    /// `papas watch ...` (live progress from a run's trace journal)
    Watch(Args),
    /// `papas help` / no args.
    Help,
}

/// Switches (no value) per subcommand; everything else starting with
/// `--` takes a value.
const SWITCHES: &[&str] = &[
    "fresh", "dot", "quiet", "concat", "gantt", "resume", "complete-only",
    "desc", "infer-timeouts", "compact", "replay", "search", "trace", "once",
];

impl Args {
    /// Parse a full argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<ParsedCommand> {
        let Some(cmd) = argv.first() else {
            return Ok(ParsedCommand::Help);
        };
        let rest = Self::parse_rest(&argv[1..])?;
        match cmd.as_str() {
            "run" => Ok(ParsedCommand::Run(rest)),
            "resume" => Ok(ParsedCommand::Resume(rest)),
            "validate" => Ok(ParsedCommand::Validate(rest)),
            "combos" => Ok(ParsedCommand::Combos(rest)),
            "instance" => Ok(ParsedCommand::Instance(rest)),
            "viz" => Ok(ParsedCommand::Viz(rest)),
            "worker" => Ok(ParsedCommand::Worker(rest)),
            "qsim" => Ok(ParsedCommand::Qsim(rest)),
            "aggregate" => Ok(ParsedCommand::Aggregate(rest)),
            "dax" => Ok(ParsedCommand::Dax(rest)),
            "status" => Ok(ParsedCommand::Status(rest)),
            "harvest" => Ok(ParsedCommand::Harvest(rest)),
            "query" => Ok(ParsedCommand::Query(rest)),
            "report" => Ok(ParsedCommand::Report(rest)),
            "search" => Ok(ParsedCommand::Search(rest)),
            "synth" => Ok(ParsedCommand::Synth(rest)),
            "doctor" => Ok(ParsedCommand::Doctor(rest)),
            "trace" => Ok(ParsedCommand::Trace(rest)),
            "watch" => Ok(ParsedCommand::Watch(rest)),
            "help" | "--help" | "-h" => Ok(ParsedCommand::Help),
            other => Err(Error::Exec(format!(
                "unknown subcommand '{other}' (try 'papas help')"
            ))),
        }
    }

    fn parse_rest(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if SWITCHES.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let value = argv.get(i + 1).ok_or_else(|| {
                        Error::Exec(format!("option --{name} needs a value"))
                    })?;
                    out.options.insert(name.to_string(), value.clone());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Option with a default.
    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Parsed numeric option with a default.
    pub fn opt_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Exec(format!("option --{key}: bad value '{v}'"))
            }),
        }
    }

    /// Is a switch present?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// First positional argument or error.
    pub fn require_positional(&self, what: &str) -> Result<&str> {
        self.positional
            .first()
            .map(String::as_str)
            .ok_or_else(|| Error::Exec(format!("missing {what}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommands() {
        assert!(matches!(Args::parse(&sv(&["run", "s.yaml"])).unwrap(), ParsedCommand::Run(_)));
        assert!(matches!(Args::parse(&sv(&["help"])).unwrap(), ParsedCommand::Help));
        assert!(matches!(Args::parse(&[]).unwrap(), ParsedCommand::Help));
        assert!(Args::parse(&sv(&["destroy"])).is_err());
        assert!(matches!(
            Args::parse(&sv(&["harvest", "s.yaml"])).unwrap(),
            ParsedCommand::Harvest(_)
        ));
        assert!(matches!(
            Args::parse(&sv(&["query", "s.yaml"])).unwrap(),
            ParsedCommand::Query(_)
        ));
        assert!(matches!(
            Args::parse(&sv(&["report", "s.yaml"])).unwrap(),
            ParsedCommand::Report(_)
        ));
        assert!(matches!(
            Args::parse(&sv(&["search", "s.yaml"])).unwrap(),
            ParsedCommand::Search(_)
        ));
        assert!(matches!(
            Args::parse(&sv(&["synth"])).unwrap(),
            ParsedCommand::Synth(_)
        ));
        assert!(matches!(
            Args::parse(&sv(&["trace", "s"])).unwrap(),
            ParsedCommand::Trace(_)
        ));
        assert!(matches!(
            Args::parse(&sv(&["doctor", "s"])).unwrap(),
            ParsedCommand::Doctor(_)
        ));
        assert!(matches!(
            Args::parse(&sv(&["watch", "s"])).unwrap(),
            ParsedCommand::Watch(_)
        ));
    }

    #[test]
    fn trace_flags_parse() {
        let ParsedCommand::Trace(a) = Args::parse(&sv(&[
            "trace", ".papas/s", "--run", "2", "--export", "chrome", "--out",
            "t.json",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(a.opt_num::<u32>("run", 0).unwrap(), 2);
        assert_eq!(a.opt_or("export", "summary"), "chrome");
        assert_eq!(a.opt_or("out", ""), "t.json");
        let ParsedCommand::Run(r) =
            Args::parse(&sv(&["run", "s.yaml", "--trace"])).unwrap()
        else {
            panic!()
        };
        assert!(r.has_flag("trace"));
        let ParsedCommand::Watch(w) =
            Args::parse(&sv(&["watch", "s", "--once"])).unwrap()
        else {
            panic!()
        };
        assert!(w.has_flag("once"));
    }

    #[test]
    fn doctor_and_serve_flags_parse() {
        let ParsedCommand::Doctor(a) = Args::parse(&sv(&[
            "doctor", ".papas/s", "--run", "2", "--format", "json",
            "--mem-budget", "1048576",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(a.opt_num::<u32>("run", 0).unwrap(), 2);
        assert_eq!(a.opt_or("format", "text"), "json");
        assert_eq!(a.opt_num::<f64>("mem-budget", 0.0).unwrap(), 1048576.0);
        let ParsedCommand::Status(s) = Args::parse(&sv(&[
            "status", ".papas/s", "--serve", "127.0.0.1:9090", "--once",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(s.opt_or("serve", ""), "127.0.0.1:9090");
        assert!(s.has_flag("once"));
    }

    #[test]
    fn synth_flags_parse() {
        let ParsedCommand::Synth(a) = Args::parse(&sv(&[
            "synth", "--seed", "7", "--count", "50", "--shape", "diamond",
            "--replay", "--workers", "2",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(a.opt_num::<u64>("seed", 42).unwrap(), 7);
        assert_eq!(a.opt_num::<u64>("count", 1).unwrap(), 50);
        assert_eq!(a.opt_or("shape", ""), "diamond");
        assert!(a.has_flag("replay"));
        assert!(!a.has_flag("search"));
    }

    #[test]
    fn search_flags_parse() {
        let ParsedCommand::Search(a) = Args::parse(&sv(&[
            "search", "s.yaml", "--rounds", "6", "--budget", "8", "--seed",
            "7", "--strategy", "halving 2", "--objective", "minimize score",
            "--resume",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(a.opt_num::<u32>("rounds", 0).unwrap(), 6);
        assert_eq!(a.opt_num::<u64>("budget", 0).unwrap(), 8);
        assert_eq!(a.opt_or("strategy", ""), "halving 2");
        assert_eq!(a.opt_or("objective", ""), "minimize score");
        assert!(a.has_flag("resume"));
    }

    #[test]
    fn query_flags_parse() {
        let ParsedCommand::Query(a) = Args::parse(&sv(&[
            "query", "s.yaml", "--where", "threads==4 && wall_time<2",
            "--by", "threads,size", "--metric", "wall_time", "--format",
            "csv", "--top", "5", "--sort", "wall_time", "--desc",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(a.opt_or("where", ""), "threads==4 && wall_time<2");
        assert_eq!(a.opt_or("by", ""), "threads,size");
        assert_eq!(a.opt_or("format", "table"), "csv");
        assert_eq!(a.opt_num::<usize>("top", 0).unwrap(), 5);
        assert!(a.has_flag("desc"));
    }

    #[test]
    fn options_flags_positionals() {
        let ParsedCommand::Run(a) = Args::parse(&sv(&[
            "run", "study.yaml", "--workers", "4", "--fresh", "extra.yaml",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(a.positional, vec!["study.yaml", "extra.yaml"]);
        assert_eq!(a.opt_or("workers", "1"), "4");
        assert_eq!(a.opt_num::<usize>("workers", 1).unwrap(), 4);
        assert!(a.has_flag("fresh"));
        assert!(!a.has_flag("dot"));
        assert!(!a.has_flag("resume"));
        assert_eq!(a.require_positional("study file").unwrap(), "study.yaml");
    }

    #[test]
    fn fault_flags_parse_as_switch_and_options() {
        let ParsedCommand::Run(a) = Args::parse(&sv(&[
            "run", "s.yaml", "--resume", "--timeout", "30", "--retries", "2",
            "--on-failure", "retry-budget:5", "--backoff", "100",
        ]))
        .unwrap() else {
            panic!()
        };
        assert!(a.has_flag("resume"));
        assert_eq!(a.opt_num::<f64>("timeout", 0.0).unwrap(), 30.0);
        assert_eq!(a.opt_num::<u32>("retries", 0).unwrap(), 2);
        assert_eq!(a.opt_or("on-failure", "continue"), "retry-budget:5");
        assert_eq!(a.opt_num::<u64>("backoff", 0).unwrap(), 100);
    }

    #[test]
    fn scheduling_flags_parse_as_switch_and_options() {
        let ParsedCommand::Run(a) = Args::parse(&sv(&[
            "run", "s.yaml", "--pack", "lpt", "--infer-timeouts",
            "--timeout-factor", "3", "--window", "64",
        ]))
        .unwrap() else {
            panic!()
        };
        assert_eq!(a.opt_or("pack", "auto"), "lpt");
        assert!(a.has_flag("infer-timeouts"));
        assert_eq!(a.opt_num::<f64>("timeout-factor", 4.0).unwrap(), 3.0);
        let ParsedCommand::Harvest(h) =
            Args::parse(&sv(&["harvest", "s.yaml", "--compact"])).unwrap()
        else {
            panic!()
        };
        assert!(h.has_flag("compact"));
    }

    #[test]
    fn missing_value_and_bad_number() {
        assert!(Args::parse(&sv(&["run", "--workers"])).is_err());
        let ParsedCommand::Run(a) =
            Args::parse(&sv(&["run", "--workers", "abc"])).unwrap()
        else {
            panic!()
        };
        assert!(a.opt_num::<usize>("workers", 1).is_err());
    }

    #[test]
    fn defaults() {
        let ParsedCommand::Run(a) = Args::parse(&sv(&["run", "x"])).unwrap() else {
            panic!()
        };
        assert_eq!(a.opt_or("mode", "local"), "local");
        assert_eq!(a.opt_num::<u64>("seed", 42).unwrap(), 42);
        assert!(Args::parse(&sv(&["run"])).is_ok());
        let ParsedCommand::Run(b) = Args::parse(&sv(&["run"])).unwrap() else {
            panic!()
        };
        assert!(b.require_positional("study file").is_err());
    }
}
