//! CLI subcommand implementations.

use super::args::Args;
use crate::cluster::{BatchJob, ClusterSim, Regime, SimConfig};
use crate::exec::runner::{RunConfig, TaskRunner};
use crate::exec::ssh::WorkerDaemon;
use crate::runtime::RuntimeService;
use crate::study::Study;
use crate::tasks::Builtins;
use crate::util::error::{Error, Result};
use crate::viz::{render_ascii, render_dot, DagView};
use crate::workflow::ExecOrder;
use std::path::PathBuf;
use std::sync::Arc;

/// Help text.
pub const USAGE: &str = "\
papas — parallel parameter studies (PEARC'18 reproduction)

USAGE:
  papas run STUDY.yaml [overlay.yaml ...] [--workers N] [--mode local|mpi|ssh]
            [--nnodes N] [--ppnode P] [--hosts a:p,b:p] [--artifacts DIR]
            [--db DIR] [--fresh] [--shard I/N] [--order dfs|bfs] [--window N]
            [--timeout S] [--retries N] [--backoff MS] [--resume]
            [--on-failure fail-fast|continue|retry-budget:N]
            [--pack auto|fifo|lpt] [--infer-timeouts] [--timeout-factor F]
            [--trace]                       journal scheduler/task events to
                                            trace-<run>.jsonl in the study db
                                            and embed a metrics snapshot in
                                            report.json (WDL: trace: true)
                                            --pack lpt admits longest-expected
                                            tasks first using wall times from
                                            the result store (auto: lpt once
                                            the store has evidence);
                                            --infer-timeouts gives timeout-less
                                            tasks p95 x F (default 4)
  papas resume STUDY.yaml [...]        continue from the checkpoint
  papas validate STUDY.yaml [...]      parse + validate, print warnings
  papas combos STUDY.yaml [--limit N] [--shard I/N]
                                       stream workflow instances (Fig. 6)
  papas instance STUDY.yaml IDX        materialize exactly one instance
  papas viz STUDY.yaml [--dot]         render the task DAG
  papas worker --bind HOST:PORT [--artifacts DIR]   SSH-mode worker daemon
  papas qsim --jobs N --regime optimal|serial|common [--nodes N] [--gantt]
             [--duration S] [--nnodes N] [--ppnode P] [--seed S]
  papas aggregate STUDY.yaml [--pattern RE] [--out FILE] [--concat]
                  [--complete-only]
  papas dax STUDY.yaml [--instance N]       Pegasus DAX export (§9)
  papas status [DB-DIR] [--gantt] [--format text|json]
               [--serve ADDR [--once]]      inspect a study database;
                                            --serve binds a tiny HTTP
                                            endpoint: GET /metrics is the
                                            newest trace journal folded to
                                            Prometheus text exposition,
                                            GET /status the JSON summary
                                            (--once answers one request
                                            and exits — smoke tests)
  papas harvest STUDY.yaml [--db DIR] [--compact]
                                            backfill typed results from
                                            attempts.jsonl + workdirs;
                                            --compact rewrites results.jsonl
                                            to live rows only (crash-safe)
  papas query STUDY.yaml [--where EXPR] [--by AXES] [--metric NAMES]
              [--run LATEST|ALL|ID] [--sort METRIC] [--desc] [--top K]
              [--format table|csv|json]      filter/group captured results
                                            (default --run LATEST: newest
                                            row per instance × task)
  papas report STUDY.yaml --metric M --by AXIS [--baseline AXIS=V]
               [--where EXPR] [--format text|json]
                                            per-axis performance summary
                                            (mean/std, speedup, efficiency)
  papas report STUDY.yaml --metric M --run ALL
                                            run-over-run trend of the metric;
                                            flags a >2-sigma shift of the
                                            newest run as a likely regression
  papas search STUDY.yaml [--rounds N] [--budget K] [--seed S]
               [--strategy 'random|halving [eta N]|refine']
               [--objective 'minimize|maximize METRIC'] [--resume]
               [--workers N] [--db DIR] [--fresh]
                                            adaptive round-based search:
                                            propose -> run -> score loop
                                            over the captured metrics
  papas synth [--seed S] [--count N] [--index I] [--tasks N]
              [--shape chain|fanout|fanin|diamond|layered] [--max-combos N]
              [--out DIR] [--replay] [--workers N] [--search]
                                            seeded synthetic-study generator:
                                            emits WDL YAML (byte-deterministic
                                            per seed); --replay drives each
                                            study hermetically through
                                            run/harvest/resume/search and
                                            asserts pipeline invariants
  papas trace [DB-DIR] [--run ID] [--export summary|chrome|csv] [--out FILE]
              [--width N]                   inspect a run's trace journal;
                                            chrome export opens in
                                            chrome://tracing / Perfetto
  papas watch [DB-DIR] [--run ID] [--interval S] [--once]
                                            live one-line progress from the
                                            newest trace journal (Ctrl-C or
                                            run_end to stop)
  papas doctor STUDY.yaml [--db DIR] [--run ID] [--format text|json]
               [--mem-budget KB]            diagnose a traced run: per-
                                            instance critical paths + slack,
                                            worker-seconds attributed to
                                            critical/off-critical compute,
                                            retry waste, scheduler overhead
                                            and idle, and a what-if table
                                            (task 2x faster => makespan);
                                            --mem-budget warns when a full
                                            window of the hungriest task
                                            (mean sampled RSS) would not fit
  papas help";

fn load_study(a: &Args) -> Result<Study> {
    load_study_opts(a, /*with_runtime=*/ true)
}

/// Analysis-only commands (validate/combos/viz/dax) skip PJRT startup.
fn load_study_opts(a: &Args, with_runtime: bool) -> Result<Study> {
    if a.positional.is_empty() {
        return Err(Error::Exec("missing study file".into()));
    }
    let paths: Vec<PathBuf> = a.positional.iter().map(PathBuf::from).collect();
    let mut study = Study::from_files(&paths)?;
    if let Some(db) = a.options.get("db") {
        study = study.with_db_root(db);
    }
    if let Some(shard) = a.options.get("shard") {
        let s = crate::workflow::Shard::parse(shard)?;
        study = study.shard(s.index, s.count)?;
    }
    if let Some(order) = a.options.get("order") {
        study = study.with_order(match order.as_str() {
            "dfs" | "depth" | "depth-first" => ExecOrder::DepthFirst,
            "bfs" | "breadth" | "breadth-first" => ExecOrder::BreadthFirst,
            other => {
                return Err(Error::Exec(format!(
                    "unknown --order '{other}' (dfs|bfs)"
                )))
            }
        });
    }
    if a.options.contains_key("window") {
        study = study.with_window(a.opt_num("window", 0usize)?.max(1));
    }
    if a.options.contains_key("timeout") {
        let secs: f64 = a.opt_num("timeout", 0.0)?;
        if !secs.is_finite() || secs <= 0.0 {
            return Err(Error::Exec(format!(
                "--timeout must be positive seconds, got '{secs}'"
            )));
        }
        study = study.with_timeout(secs);
    }
    if a.options.contains_key("retries") {
        study = study.with_retries(a.opt_num("retries", 0u32)?);
    }
    if let Some(raw) = a.options.get("on-failure") {
        let policy = crate::exec::FailurePolicy::parse(raw)
            .map_err(Error::Exec)?;
        study = study.with_policy(policy);
    }
    if a.options.contains_key("backoff") {
        study = study.with_backoff_ms(a.opt_num("backoff", 0u64)?);
    }
    if let Some(raw) = a.options.get("pack") {
        // "auto" = the study default: coverage-driven mode selection.
        if raw != "auto" {
            study = study.with_pack(crate::workflow::PackMode::parse(raw)?);
        }
    }
    if a.has_flag("infer-timeouts") {
        study = study.with_infer_timeouts(true);
    }
    if a.has_flag("trace") {
        study = study.with_trace(true);
    }
    if a.options.contains_key("timeout-factor") {
        let f: f64 = a.opt_num("timeout-factor", 0.0)?;
        if !f.is_finite() || f <= 0.0 {
            return Err(Error::Exec(format!(
                "--timeout-factor must be a positive number, got '{f}'"
            )));
        }
        study = study.with_timeout_multiplier(f);
    }
    if !with_runtime {
        return Ok(study);
    }
    if let Some(dir) = a.options.get("artifacts") {
        study = study.with_runtime(RuntimeService::start(dir)?);
    } else if std::path::Path::new("artifacts/manifest.json").exists() {
        study = study.with_runtime(RuntimeService::start("artifacts")?);
    }
    Ok(study)
}

/// `papas run` / `papas resume` (`papas run --resume` is the explicit
/// spelling of the latter).
pub fn cmd_run(a: &Args, resume: bool) -> Result<()> {
    let resume = resume || a.has_flag("resume");
    let study = load_study(a)?;
    for w in &study.warnings {
        eprintln!("warning: {w}");
    }
    if a.has_flag("fresh") && !resume {
        study.clear_checkpoint()?;
    }
    if resume {
        let ckpt = crate::study::Checkpoint::load(&study.db_root)?;
        if !ckpt.done_keys.is_empty() || !ckpt.failed_keys.is_empty() {
            println!(
                "resume: {} tasks already done (skipped), {} previously \
                 failed will re-run",
                ckpt.done_keys.len(),
                ckpt.failed_keys.len()
            );
        }
    }
    let mode = a.opt_or("mode", "local");
    let shard = study.shard_config();
    println!(
        "study '{}': {} combinations, {} selected instances{}, mode={mode}",
        study.name,
        study.space().len(),
        study.n_instances(),
        if shard.is_whole() {
            String::new()
        } else {
            format!(" (shard {shard})")
        }
    );
    let report = match mode.as_str() {
        "local" => study.run_local(a.opt_num("workers", 2)?),
        "mpi" => study.run_mpi(a.opt_num("nnodes", 1)?, a.opt_num("ppnode", 2)?),
        "ssh" => {
            let hosts: Vec<String> = a
                .opt_or("hosts", "")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            study.run_ssh(&hosts, a.opt_num("workers", 2)?)
        }
        other => Err(Error::Exec(format!("unknown mode '{other}'"))),
    }?;
    println!(
        "done: {} completed, {} failed, {} skipped, {} restored{} | makespan \
         {:.3}s | utilization {:.0}%",
        report.completed,
        report.failed,
        report.skipped,
        report.restored,
        if report.halted { " | HALTED (fail-fast)" } else { "" },
        report.makespan,
        report.utilization * 100.0
    );
    if report.halted {
        return Err(Error::Exec(
            "run halted by fail-fast; re-run with --resume to continue the \
             remainder"
                .into(),
        ));
    }
    if !report.all_ok() {
        return Err(Error::Exec("some tasks failed".into()));
    }
    Ok(())
}

/// `papas validate`.
pub fn cmd_validate(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    println!(
        "OK: {} tasks, {} parameters, {} combinations, {} selected",
        study.spec.tasks.len(),
        study.space().params().len(),
        study.space().len(),
        study.n_instances()
    );
    for w in &study.warnings {
        println!("warning: {w}");
    }
    Ok(())
}

/// `papas combos` — the Figure 6 enumeration, streamed: instances are
/// materialized one at a time and dropped after printing, so a `--limit`
/// over a huge space costs O(limit), not O(N_W).
pub fn cmd_combos(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    let limit: u64 = a.opt_num("limit", u64::MAX)?;
    let source = study.source();
    for inst in source.iter().take(limit.min(source.len()) as usize) {
        let inst = inst?;
        for cmd in inst.command_lines() {
            println!("{}: {cmd}", inst.display_id());
        }
    }
    println!("# {} workflow instances", source.len());
    Ok(())
}

/// `papas instance STUDY.yaml IDX` — materialize exactly one workflow
/// instance (the IDX-th of the selection) without touching the rest of
/// the space.
pub fn cmd_instance(a: &Args) -> Result<()> {
    // The trailing positional is the index; the rest are study files.
    let mut a = a.clone();
    let idx: u64 = if a.positional.len() > 1 {
        let raw = a.positional.pop().unwrap();
        raw.parse().map_err(|_| {
            Error::Exec(format!("bad instance index '{raw}'"))
        })?
    } else {
        a.opt_num("index", 0)?
    };
    let study = load_study_opts(&a, false)?;
    let inst = study.instance_at(idx)?;
    println!("{} (combination {})", inst.display_id(), inst.index);
    for (k, v) in inst.combo.pairs() {
        println!("  {k} = {v}");
    }
    for cmd in inst.command_lines() {
        println!("  $ {cmd}");
    }
    Ok(())
}

/// `papas viz` — all instances share one task graph, so only the first
/// is materialized.
pub fn cmd_viz(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    if study.n_instances() == 0 {
        return Err(Error::Exec("study has no instances".into()));
    }
    let first = study.instance_at(0)?;
    let view = DagView::pending(&first.dag);
    if a.has_flag("dot") {
        print!("{}", render_dot(&view, &study.name));
    } else {
        print!("{}", render_ascii(&view));
        println!(
            "({} instances share this task graph)",
            study.n_instances()
        );
    }
    Ok(())
}

/// `papas worker` — the SSH-mode daemon.
pub fn cmd_worker(a: &Args) -> Result<()> {
    let bind = a
        .options
        .get("bind")
        .ok_or_else(|| Error::Exec("worker needs --bind HOST:PORT".into()))?;
    let builtins = match a.options.get("artifacts") {
        Some(dir) => Arc::new(Builtins::with_runtime(RuntimeService::start(dir)?)),
        None => Arc::new(Builtins::without_runtime()),
    };
    let runner = Arc::new(TaskRunner::new(
        builtins,
        RunConfig {
            work_root: PathBuf::from(a.opt_or("work", ".papas-worker")),
            input_root: PathBuf::from(a.opt_or("inputs", ".")),
        },
    ));
    let daemon = WorkerDaemon::bind(bind, runner)?;
    println!("LISTENING {}", daemon.addr);
    daemon.serve()
}

/// `papas qsim` — drive the cluster simulator directly (Figure 1 shapes).
pub fn cmd_qsim(a: &Args) -> Result<()> {
    let jobs: usize = a.opt_num("jobs", 25)?;
    let regime = Regime::parse(&a.opt_or("regime", "common"))
        .ok_or_else(|| Error::Exec("bad --regime (optimal|serial|common)".into()))?;
    let nodes: usize = a.opt_num("nodes", 6)?;
    let duration: f64 = a.opt_num("duration", 1800.0)?;
    let seed: u64 = a.opt_num("seed", 42)?;
    let mut sim = ClusterSim::new(SimConfig::new(nodes, regime, seed))?;
    if a.options.contains_key("nnodes") || a.options.contains_key("ppnode") {
        // grouped: one job carrying all tasks
        let n: usize = a.opt_num("nnodes", 1)?;
        let p: usize = a.opt_num("ppnode", 1)?;
        sim.submit(BatchJob::uniform("grouped", n, p, jobs, duration))?;
    } else {
        for i in 0..jobs {
            sim.submit(BatchJob::uniform(format!("job{i:02}"), 1, 1, 1, duration))?;
        }
    }
    let traces = sim.run_to_completion();
    println!("# regime={} nodes={nodes} seed={seed}", regime.name());
    if a.has_flag("gantt") {
        print!("{}", crate::viz::render_jobs(&traces, 72));
    } else {
        println!("job,name,submit,start,end");
        for t in &traces {
            println!("{},{},{:.1},{:.1},{:.1}", t.id, t.name, t.submit, t.start, t.end);
        }
    }
    println!(
        "# makespan={:.1}s interactions={}",
        crate::cluster::job::makespan(&traces),
        crate::cluster::job::scheduler_interactions(&traces)
    );
    Ok(())
}

/// Resolve `papas status|trace|watch NAME` to a study database root:
/// an existing path is used as-is, anything else is looked up under
/// `--db` (default `.papas`).
fn resolve_db(a: &Args) -> PathBuf {
    let db = PathBuf::from(a.opt_or("db", ".papas"));
    if a.positional.is_empty() {
        db
    } else {
        let p = PathBuf::from(&a.positional[0]);
        if p.exists() { p } else { db.join(&a.positional[0]) }
    }
}

/// The `papas status --format json` summary document, recomputed from
/// the study database on every call (so the `--serve` `/status` route
/// always reflects current state).
fn status_json(db: &std::path::Path) -> Result<crate::json::Json> {
    use crate::json::Json;
    let filedb = crate::study::FileDb::open(db)?;
    let snap = filedb.load_study_snapshot().map_err(|_| {
        Error::Store(format!("no study database under {}", db.display()))
    })?;
    let ckpt = crate::study::Checkpoint::load(db)?;
    let prov = crate::workflow::provenance::Provenance::open(db)?;
    let attempts = prov.read_attempts()?;
    let retries = attempts.iter().filter(|a| a.attempt > 1).count();
    let mut by_class: std::collections::BTreeMap<&str, usize> =
        std::collections::BTreeMap::new();
    for at in &attempts {
        if let Some(c) = at.class {
            *by_class.entry(c.label()).or_insert(0) += 1;
        }
    }
    let records = prov.read_records()?;
    let records_ok = records.iter().filter(|r| r.ok).count();
    let last_run: Option<Json> = if db.join("report.json").exists() {
        Some(crate::json::parse(&std::fs::read_to_string(
            db.join("report.json"),
        )?)?)
    } else {
        None
    };
    Ok(Json::obj([
            ("name".to_string(), snap.expect("name")?.clone()),
            (
                "n_combinations".to_string(),
                snap.expect("n_combinations")?.clone(),
            ),
            ("n_selected".to_string(), snap.expect("n_selected")?.clone()),
            (
                "checkpoint".to_string(),
                Json::obj([
                    ("done".to_string(), Json::from(ckpt.done_keys.len())),
                    ("failed".to_string(), Json::from(ckpt.failed_keys.len())),
                ]),
            ),
            (
                "attempts".to_string(),
                Json::obj([
                    ("total".to_string(), Json::from(attempts.len())),
                    ("retries".to_string(), Json::from(retries)),
                    (
                        "failures_by_class".to_string(),
                        Json::Obj(
                            by_class
                                .iter()
                                .map(|(k, v)| (k.to_string(), Json::from(*v)))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "records".to_string(),
                Json::obj([
                    ("total".to_string(), Json::from(records.len())),
                    ("ok".to_string(), Json::from(records_ok)),
                    (
                        "failed".to_string(),
                        Json::from(records.len() - records_ok),
                    ),
                ]),
            ),
            (
                "last_run".to_string(),
                last_run.clone().unwrap_or(Json::Null),
            ),
            (
                "results".to_string(),
                match crate::results::store::stored_row_count(db) {
                    Some(n) => {
                        Json::obj([("rows".to_string(), Json::from(n))])
                    }
                    None => Json::Null,
                },
            ),
        ]))
}

/// `papas status` — inspect a study's file database (monitoring view).
/// `--format json` emits the same summary as one machine-readable JSON
/// document (CI gates, external dashboards); `--serve ADDR` exports it
/// over HTTP alongside a Prometheus `/metrics` endpoint.
pub fn cmd_status(a: &Args) -> Result<()> {
    use crate::json::Json;
    let db = resolve_db(a);
    if let Some(addr) = a.options.get("serve") {
        return serve_status(&db, addr, a.has_flag("once"));
    }
    let as_json = match a.opt_or("format", "text").as_str() {
        "text" => false,
        "json" => true,
        other => {
            return Err(Error::Exec(format!(
                "unknown --format '{other}' (text|json)"
            )))
        }
    };
    if as_json {
        println!(
            "{}",
            crate::json::to_string_pretty(&status_json(&db)?)
        );
        return Ok(());
    }

    let filedb = crate::study::FileDb::open(&db)?;
    let snap = filedb.load_study_snapshot().map_err(|_| {
        Error::Store(format!("no study database under {}", db.display()))
    })?;
    let ckpt = crate::study::Checkpoint::load(&db)?;
    let prov = crate::workflow::provenance::Provenance::open(&db)?;
    let attempts = prov.read_attempts()?;
    let retries = attempts.iter().filter(|a| a.attempt > 1).count();
    let mut by_class: std::collections::BTreeMap<&str, usize> =
        std::collections::BTreeMap::new();
    for at in &attempts {
        if let Some(c) = at.class {
            *by_class.entry(c.label()).or_insert(0) += 1;
        }
    }
    let records = prov.read_records()?;
    let records_ok = records.iter().filter(|r| r.ok).count();
    let last_run: Option<Json> = if db.join("report.json").exists() {
        Some(crate::json::parse(&std::fs::read_to_string(
            db.join("report.json"),
        )?)?)
    } else {
        None
    };

    println!(
        "study '{}': {} combinations, {} selected",
        snap.expect_str("name")?,
        snap.expect_i64("n_combinations")?,
        snap.expect_i64("n_selected")?
    );
    println!(
        "checkpoint: {} tasks completed, {} failed terminally",
        ckpt.done_keys.len(),
        ckpt.failed_keys.len()
    );
    if !attempts.is_empty() {
        let classes = by_class
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "attempts: {} total, {} retries{}",
            attempts.len(),
            retries,
            if classes.is_empty() {
                String::new()
            } else {
                format!(" | failures by class: {classes}")
            }
        );
    }
    if !records.is_empty() {
        println!(
            "records: {} total, {} ok, {} failed",
            records.len(),
            records_ok,
            records.len() - records_ok
        );
        if a.has_flag("gantt") {
            let tail: Vec<_> =
                records.iter().rev().take(30).rev().cloned().collect();
            print!("{}", crate::viz::render_records(&tail, 60));
        }
    }
    if let Some(j) = &last_run {
        println!(
            "last run: {} completed / {} failed / {} restored on {} \
             (makespan {:.3}s)",
            j.expect_i64("completed")?,
            j.expect_i64("failed")?,
            j.expect_i64("restored")?,
            j.expect_str("executor")?,
            j.expect("makespan_s")?.as_f64().unwrap_or(0.0),
        );
        // Per-worker busy/idle split (reports written before the
        // elastic-scheduling change carry no workers array).
        if let Some(Json::Arr(ws)) = j.get("workers") {
            for w in ws {
                println!(
                    "  worker {}: {} tasks | busy {:.3}s, idle {:.3}s \
                     ({:.0}% utilized)",
                    w.expect_str("worker")?,
                    w.expect_i64("tasks")?,
                    w.expect("busy_s")?.as_f64().unwrap_or(0.0),
                    w.expect("idle_s")?.as_f64().unwrap_or(0.0),
                    w.expect("utilization")?.as_f64().unwrap_or(0.0) * 100.0,
                );
            }
        }
    }
    Ok(())
}

/// `papas status --serve ADDR`: bind a plain TCP listener and answer
/// `GET /metrics` (the newest trace journal folded into Prometheus
/// text exposition on every scrape) and `GET /status` (the JSON
/// summary). `once` answers a single request and returns.
fn serve_status(
    db: &std::path::Path,
    addr: &str,
    once: bool,
) -> Result<()> {
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| Error::Exec(format!("--serve {addr}: {e}")))?;
    println!(
        "serving http://{} (GET /metrics, GET /status){}",
        listener.local_addr()?,
        if once { " — one request" } else { "" }
    );
    let metrics_db = db.to_path_buf();
    let metrics = move || {
        let m = crate::obs::latest_trace_run(&metrics_db)
            .and_then(|run| {
                crate::obs::read_trace(&crate::obs::trace_path(
                    &metrics_db,
                    run,
                ))
                .ok()
            })
            .map(|events| crate::obs::fold_trace(&events))
            .unwrap_or_default();
        crate::obs::render_prometheus(&m)
    };
    let status_db = db.to_path_buf();
    let status = move || match status_json(&status_db) {
        Ok(j) => crate::json::to_string_pretty(&j),
        Err(e) => crate::json::to_string(&crate::json::Json::obj([(
            "error".to_string(),
            crate::json::Json::from(e.to_string().as_str()),
        )])),
    };
    crate::obs::serve::serve(listener, once, &metrics, &status)
}

/// `papas aggregate` — the §9 output-aggregation extension.
pub fn cmd_aggregate(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    let pattern = a.opt_or("pattern", r".*\.csv$");
    let out = PathBuf::from(a.opt_or("out", "aggregate.csv"));
    let mode = if a.has_flag("concat") {
        crate::study::AggregateMode::Concat
    } else {
        crate::study::AggregateMode::Csv
    };
    let n = crate::study::aggregate_filtered(
        &study,
        &pattern,
        mode,
        &out,
        a.has_flag("complete-only"),
    )?;
    println!("aggregated {n} files matching '{pattern}' -> {}", out.display());
    Ok(())
}

/// `papas harvest` — backfill the typed result store from the attempt
/// log and the instance workdirs (post-hoc capture). `--compact`
/// reports the row-log rewrite: the harvest replaces `results.jsonl`
/// (atomically, tmp + rename) with exactly the live rows, folding away
/// superseded duplicates a long append-only history accumulates.
pub fn cmd_harvest(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    let before = crate::results::log_line_count(&study.db_root);
    let table = crate::results::harvest(&study)?;
    let db = crate::study::FileDb::at(&study.db_root);
    println!(
        "harvested {} result rows × {} metric columns -> {} (+ binary \
         snapshot {})",
        table.len(),
        table.schema().metrics.len(),
        db.results_path().display(),
        db.results_bin_path().display(),
    );
    if a.has_flag("compact") {
        match before {
            Some(n) => println!(
                "compacted results.jsonl: {n} logged lines -> {} live rows",
                table.len()
            ),
            None => println!(
                "compacted results.jsonl: no prior row log -> {} live rows",
                table.len()
            ),
        }
    }
    Ok(())
}

/// Load the study's result table, harvesting on demand when **no store
/// exists at all** (first `papas query` after a run without a
/// `capture:` block). An *existing but unloadable* store propagates its
/// error instead — harvest rewrites `results.jsonl`, and a query must
/// never destructively replace previously captured values (file metrics
/// whose workdirs are gone would re-extract as missing).
fn load_results(
    study: &crate::study::Study,
) -> Result<(crate::results::CaptureEngine, crate::results::ResultTable)> {
    let engine = study.capture_engine()?;
    let db = crate::study::FileDb::at(&study.db_root);
    if !db.results_path().exists()
        && !db.results_bin_path().exists()
        && !db.results_columns_path().exists()
    {
        let t = crate::results::harvest(study)?;
        eprintln!(
            "note: no result store found; harvested {} rows from \
             attempts.jsonl",
            t.len()
        );
        return Ok((engine, t));
    }
    let t = crate::results::ResultTable::load(&study.db_root, engine.schema())?;
    Ok((engine, t))
}

/// `papas query` — filter/group/aggregate the captured result set.
pub fn cmd_query(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    let (engine, table) = load_results(&study)?;
    let format = crate::results::Format::parse(&a.opt_or("format", "table"))?;
    let top = match a.options.get("top") {
        Some(_) => Some(a.opt_num::<usize>("top", 0)?),
        None => None,
    };
    let mut query = crate::results::Query::parse(
        engine.schema(),
        study.space(),
        &a.opt_or("where", ""),
        &a.opt_or("by", ""),
        &a.opt_or("metric", ""),
        a.options.get("sort").map(String::as_str),
        a.has_flag("desc"),
        top,
    )?;
    query.run = crate::results::RunSel::parse(&a.opt_or("run", ""))?;
    if query.by.is_empty() {
        let rows = crate::results::run_flat(&table, study.space(), &query);
        print!(
            "{}",
            crate::results::render_flat(&rows, engine.schema(), &query, format)
        );
        if format == crate::results::Format::Table {
            println!("# {} rows of {}", rows.len(), table.len());
        }
    } else {
        let groups =
            crate::results::run_grouped(&table, study.space(), &query)?;
        print!("{}", crate::results::render_groups(&groups, format));
        if format == crate::results::Format::Table {
            println!("# {} groups over {} rows", groups.len(), table.len());
        }
    }
    Ok(())
}

/// `papas report` — the §6-style performance summary: one axis, one
/// metric, mean/std per axis value, speedup + parallel efficiency
/// against `--baseline AXIS=VALUE`, and an ASCII trend.
pub fn cmd_report(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    let (engine, table) = load_results(&study)?;
    let metric = a.opt_or("metric", "wall_time");
    // `--run ALL`: longitudinal trend — one aggregate row per run id,
    // newest run checked for a >2σ shift against the prior runs.
    if a.opt_or("run", "").eq_ignore_ascii_case("all") {
        let trend =
            crate::results::build_trend(&table, engine.schema(), &metric)?;
        match a.opt_or("format", "text").as_str() {
            "text" => print!("{}", trend.render_text()),
            "json" => println!(
                "{}",
                crate::json::to_string_pretty(&trend.to_json())
            ),
            other => {
                return Err(Error::Exec(format!(
                    "unknown --format '{other}' (text|json)"
                )))
            }
        }
        return Ok(());
    }
    let by = a.options.get("by").ok_or_else(|| {
        Error::Exec("report needs --by AXIS (e.g. --by threads)".into())
    })?;
    let report = crate::results::build_report(
        &table,
        study.space(),
        engine.schema(),
        &metric,
        by,
        a.options.get("baseline").map(String::as_str),
        &a.opt_or("where", ""),
    )?;
    match a.opt_or("format", "text").as_str() {
        "text" => print!("{}", report.render_text()),
        "json" => {
            println!("{}", crate::json::to_string_pretty(&report.to_json()))
        }
        other => {
            return Err(Error::Exec(format!(
                "unknown --format '{other}' (text|json)"
            )))
        }
    }
    Ok(())
}

/// `papas search` — the adaptive round-based study driver: propose →
/// run (pinned sub-study) → harvest → score, looping until the round
/// cap or convergence. Prints a live per-round incumbent table and a
/// final best-combination report with the incumbent-score trend.
pub fn cmd_search(a: &Args) -> Result<()> {
    use crate::search::{
        run_search_observed, Objective, SearchConfig, StrategySpec,
    };
    let study = load_study(a)?;
    for w in &study.warnings {
        eprintln!("warning: {w}");
    }
    // WDL `search:` block (defaults when absent), CLI flags override.
    let spec = study.search_spec().cloned().unwrap_or_default();
    let mut cfg = SearchConfig::from_spec(&spec);
    if let Some(o) = a.options.get("objective") {
        cfg.objective = Objective::parse(o)?;
    }
    if let Some(s) = a.options.get("strategy") {
        cfg.strategy = StrategySpec::parse(s)?;
    }
    cfg.rounds = a.opt_num("rounds", cfg.rounds)?;
    cfg.budget = a.opt_num("budget", cfg.budget)?;
    cfg.seed = a.opt_num("seed", cfg.seed)?;
    cfg.resume = a.has_flag("resume");
    // A fresh search leaves the shared study checkpoint alone (already
    // completed tasks restore with their recorded metrics); `--fresh`
    // forces full re-execution, mirroring `papas run --fresh`.
    if a.has_flag("fresh") && !cfg.resume {
        study.clear_checkpoint()?;
    }

    println!(
        "search '{}': {} combinations | {} | strategy {} | up to {} rounds \
         x budget {}{}",
        study.name,
        study.space().len(),
        cfg.objective,
        cfg.strategy,
        cfg.rounds,
        cfg.budget,
        if cfg.resume { " (resume)" } else { "" }
    );
    let executor = study.local_executor(a.opt_num("workers", 2)?);
    let objective = cfg.objective.clone();
    println!("round  proposed  scored  round-best    incumbent");
    let outcome = run_search_observed(&study, &cfg, &executor, |rec| {
        let scores = rec.scores.as_deref().unwrap_or(&[]);
        let round_best = scores
            .iter()
            .flatten()
            .copied()
            .reduce(|a, b| if objective.better(b, a) { b } else { a });
        let fmt = |s: Option<f64>| match s {
            Some(x) => crate::util::strings::fmt_number(x),
            None => "-".to_string(),
        };
        let incumbent = match rec.incumbent {
            Some((i, s)) => {
                format!("#{i} = {}", crate::util::strings::fmt_number(s))
            }
            None => "-".to_string(),
        };
        println!(
            "{:>5}  {:>8}  {:>6}  {:>10}    {incumbent}",
            rec.round,
            rec.proposals.len(),
            scores.iter().flatten().count(),
            fmt(round_best),
        );
    })?;

    let Some((best, score)) = outcome.best() else {
        return Err(Error::Exec(format!(
            "search finished but no combination produced a scoreable \
             '{}' metric",
            cfg.objective.metric
        )));
    };
    println!(
        "{} after {} round(s), {} task executions ({} of {} combinations \
         ever run)",
        if outcome.converged { "converged" } else { "round cap reached" },
        outcome.history.rounds_completed(),
        outcome.executions,
        outcome.history.n_proposed(),
        study.space().len()
    );
    println!(
        "best: combination {best} ({} = {})",
        cfg.objective.metric,
        crate::util::strings::fmt_number(score)
    );
    for (k, v) in study.space().combination(best)? {
        println!("  {k} = {v}");
    }
    // Incumbent-score trend over rounds (same renderer as `papas report`).
    let rows: Vec<(String, f64)> = outcome
        .history
        .rounds()
        .iter()
        .filter_map(|r| {
            r.incumbent.map(|(_, s)| (format!("round {}", r.round), s))
        })
        .collect();
    print!("{}", crate::viz::render_bars(&rows, 40));
    Ok(())
}

/// `papas dax` — the §9 Pegasus-integration extension. Materializes only
/// the requested instance, not the whole selection.
pub fn cmd_dax(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    let idx: u64 = a.opt_num("instance", 0)?;
    // instance_at reports out-of-range indices itself; other errors
    // (interpolation failures etc.) propagate undisguised.
    let inst = study.instance_at(idx)?;
    print!("{}", crate::viz::render_dax(&inst, &study.name));
    Ok(())
}

/// `papas synth` — the seeded synthetic-study generator. Without
/// `--replay` it emits WDL YAML (to stdout, or one file per study under
/// `--out DIR`); with `--replay` each generated study is driven
/// hermetically through run → harvest → checkpoint-resume → search by
/// [`crate::synth::replay`], which errors on any pipeline-invariant
/// violation — the CI front-door smoke.
pub fn cmd_synth(a: &Args) -> Result<()> {
    use crate::synth::{self, replay::ReplayConfig, Shape, SynthConfig};
    let seed: u64 = a.opt_num("seed", 42)?;
    let count: u64 = a.opt_num("count", 1)?.max(1);
    let start: u64 = a.opt_num("index", 0)?;
    let mut base = SynthConfig { seed, ..SynthConfig::default() };
    if a.options.contains_key("tasks") {
        base.n_tasks = Some(a.opt_num("tasks", 2usize)?.max(1));
    }
    if let Some(sh) = a.options.get("shape") {
        base.shape = Some(Shape::parse(sh).ok_or_else(|| {
            Error::Exec(format!(
                "--shape: unknown shape '{sh}' \
                 (chain|fanout|fanin|diamond|layered)"
            ))
        })?);
    }
    base.max_instances = a.opt_num("max-combos", base.max_instances)?.max(1);

    let out_dir = a.options.get("out").map(PathBuf::from);
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    let replaying = a.has_flag("replay");
    let rcfg = ReplayConfig {
        workers: a.opt_num("workers", 4usize)?.max(1),
        search: a.has_flag("search"),
    };
    let scratch = out_dir
        .clone()
        .unwrap_or_else(|| std::env::temp_dir().join(format!("papas-synth-{seed}")));

    for i in start..start.saturating_add(count) {
        let s = synth::generate(&SynthConfig { index: i, ..base.clone() });
        if let Some(d) = &out_dir {
            let path = d.join(format!("{}.yaml", s.name));
            std::fs::write(&path, s.to_yaml())?;
            if !replaying {
                println!("wrote {}", path.display());
            }
        } else if !replaying {
            print!("{}", s.to_yaml());
        }
        if replaying {
            let out = synth::replay(&s, &rcfg, &scratch.join(&s.name))?;
            println!(
                "{}: shape={} tasks={} instances={} | {} done {} failed \
                 {} skipped | {} rows{}",
                out.name,
                out.shape,
                out.tasks,
                out.instances,
                out.completed,
                out.failed,
                out.skipped,
                out.rows,
                if out.searched { " | searched" } else { "" }
            );
        }
    }
    if replaying {
        println!("replayed {count} studies: all pipeline invariants held");
    }
    Ok(())
}

/// Pick the trace journal to inspect: `--run ID` or the newest one.
fn pick_trace_run(a: &Args, db: &std::path::Path) -> Result<u32> {
    match a.options.get("run") {
        Some(_) => a.opt_num::<u32>("run", 0),
        None => crate::obs::latest_trace_run(db).ok_or_else(|| {
            Error::Store(format!(
                "no trace journal under {} (run with --trace)",
                db.display()
            ))
        }),
    }
}

/// `papas trace` — inspect or export a run's trace journal (written by
/// `papas run --trace` / WDL `trace: true`).
pub fn cmd_trace(a: &Args) -> Result<()> {
    let db = resolve_db(a);
    let run = pick_trace_run(a, &db)?;
    let path = crate::obs::trace_path(&db, run);
    let events = crate::obs::read_trace(&path)?;
    if events.is_empty() {
        return Err(Error::Store(format!(
            "trace journal {} holds no events",
            path.display()
        )));
    }
    let rendered = match a.opt_or("export", "summary").as_str() {
        "summary" => crate::obs::export::render_summary(
            &events,
            a.opt_num("width", 100usize)?.max(20),
        ),
        "chrome" => crate::json::to_string_pretty(
            &crate::obs::export::to_chrome(&events),
        ),
        "csv" => crate::obs::export::to_csv(&events),
        other => {
            return Err(Error::Exec(format!(
                "unknown --export '{other}' (summary|chrome|csv)"
            )))
        }
    };
    match a.options.get("out") {
        Some(out) => {
            std::fs::write(out, rendered.as_bytes())?;
            println!("wrote {out} ({} events)", events.len());
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

/// `papas doctor` — diagnose a traced run: per-instance critical paths,
/// run-level bottleneck attribution, and a what-if speedup table, all
/// folded from the trace journal against the compiled task DAG.
pub fn cmd_doctor(a: &Args) -> Result<()> {
    let study = load_study_opts(a, false)?;
    let db = study.db_root.clone();
    let run = pick_trace_run(a, &db)?;
    let path = crate::obs::trace_path(&db, run);
    let events = crate::obs::read_trace(&path)?;
    if events.is_empty() {
        return Err(Error::Store(format!(
            "trace journal {} holds no events",
            path.display()
        )));
    }
    // Task ids and `after:` edges are fixed by the spec, so instance
    // 0's DAG is representative of every instance in the study.
    let dag = study.instance_at_naive(0)?.dag;
    let mut diag = crate::obs::diagnose(&events, &dag);
    if a.options.contains_key("mem-budget") {
        let budget = a.opt_num::<f64>("mem-budget", 0.0)?;
        if !(budget > 0.0) {
            return Err(Error::Exec(format!(
                "--mem-budget must be a positive KiB figure, got \
                 '{budget}'"
            )));
        }
        let (_, table) = load_results(&study)?;
        let model = crate::workflow::CostModel::from_table(&table);
        let ids: Vec<String> = (0..dag.len())
            .map(|i| dag.name(i).to_string())
            .collect();
        if let Some(w) = crate::obs::critical::check_mem_budget(
            &model,
            &ids,
            diag.workers,
            budget,
        ) {
            diag.warnings.push(w);
        }
    }
    match a.opt_or("format", "text").as_str() {
        "text" => print!("{}", diag.render_text()),
        "json" => {
            println!("{}", crate::json::to_string_pretty(&diag.to_json()))
        }
        other => {
            return Err(Error::Exec(format!(
                "unknown --format '{other}' (text|json)"
            )))
        }
    }
    Ok(())
}

/// `papas watch` — live progress folded from the newest trace journal.
/// Re-reads the journal each tick (reads are torn-line tolerant) and
/// prints a status line whenever it changes; exits once the run ends.
/// `--once` renders a single snapshot (scripts and tests).
pub fn cmd_watch(a: &Args) -> Result<()> {
    let db = resolve_db(a);
    let interval = a.opt_num("interval", 1.0f64)?.max(0.1);
    let once = a.has_flag("once");
    let mut last = String::new();
    loop {
        // Re-resolved each tick so a newly started run is picked up.
        let run = pick_trace_run(a, &db)?;
        let events =
            crate::obs::read_trace(&crate::obs::trace_path(&db, run))?;
        let mut state = crate::obs::WatchState::default();
        for e in &events {
            state.ingest(e);
        }
        let line = state.render();
        if line != last {
            println!("{line}");
            last = line;
        }
        if once || state.ended {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn study_file(tag: &str, content: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("papas_cli").join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("study.yaml");
        std::fs::write(&p, content).unwrap();
        p
    }

    fn args(positional: &[&str], opts: &[(&str, &str)]) -> Args {
        let mut a = Args::default();
        a.positional = positional.iter().map(|s| s.to_string()).collect();
        for (k, v) in opts {
            a.options.insert(k.to_string(), v.to_string());
        }
        a
    }

    #[test]
    fn validate_command() {
        let p = study_file("validate", "t:\n  command: sleep-ms 0\n  v: [1, 2]\n");
        let a = args(&[p.to_str().unwrap()], &[]);
        cmd_validate(&a).unwrap();
    }

    #[test]
    fn run_command_local() {
        let p = study_file("run", "t:\n  command: sleep-ms 1\n  v: [1, 2]\n");
        let db = p.parent().unwrap().join(".papas");
        let a = args(
            &[p.to_str().unwrap()],
            &[("workers", "2"), ("db", db.to_str().unwrap())],
        );
        cmd_run(&a, false).unwrap();
    }

    #[test]
    fn combos_and_viz() {
        let p = study_file(
            "combos",
            "t:\n  command: sleep-ms ${v}\n  v: [1, 2, 3]\n",
        );
        let a = args(&[p.to_str().unwrap()], &[]);
        cmd_combos(&a).unwrap();
        cmd_viz(&a).unwrap();
        // streamed --limit and --shard compose
        let a = args(&[p.to_str().unwrap()], &[("limit", "1")]);
        cmd_combos(&a).unwrap();
        let a = args(&[p.to_str().unwrap()], &[("shard", "1/2")]);
        cmd_combos(&a).unwrap();
        let a = args(&[p.to_str().unwrap()], &[("shard", "9/2")]);
        assert!(cmd_combos(&a).is_err());
    }

    #[test]
    fn instance_command_materializes_one() {
        let p = study_file(
            "instance",
            "t:\n  command: sleep-ms ${v}\n  v: [1, 2, 3]\n",
        );
        cmd_instance(&args(&[p.to_str().unwrap(), "1"], &[])).unwrap();
        // default index 0 when no positional
        cmd_instance(&args(&[p.to_str().unwrap()], &[])).unwrap();
        assert!(cmd_instance(&args(&[p.to_str().unwrap(), "99"], &[])).is_err());
        assert!(cmd_instance(&args(&[p.to_str().unwrap(), "xyz"], &[])).is_err());
    }

    #[test]
    fn run_command_sharded_splits_and_composes() {
        let p = study_file(
            "shardrun",
            "t:\n  command: sleep-ms 1\n  v: [1, 2, 3, 4]\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let dbs = db.to_str().unwrap();
        for shard in ["0/2", "1/2"] {
            let a = args(
                &[p.to_str().unwrap()],
                &[("workers", "2"), ("db", dbs), ("shard", shard)],
            );
            cmd_run(&a, false).unwrap();
        }
        // both shards checkpointed into one db: a full resume re-runs
        // nothing (checkpoint has all 4 keys)
        let a = args(&[p.to_str().unwrap()], &[("workers", "2"), ("db", dbs)]);
        cmd_run(&a, true).unwrap();
        let ckpt = crate::study::Checkpoint::load(&db).unwrap();
        assert_eq!(ckpt.done_keys.len(), 4);
    }

    #[test]
    fn run_command_order_and_window_flags() {
        let p = study_file(
            "orderwin",
            "t:\n  command: sleep-ms 1\n  v: [1, 2, 3]\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let a = args(
            &[p.to_str().unwrap()],
            &[
                ("workers", "2"),
                ("db", db.to_str().unwrap()),
                ("order", "bfs"),
                ("window", "2"),
            ],
        );
        cmd_run(&a, false).unwrap();
        let bad = args(
            &[p.to_str().unwrap()],
            &[("db", db.to_str().unwrap()), ("order", "sideways")],
        );
        assert!(cmd_run(&bad, false).is_err());
    }

    #[test]
    fn run_command_scheduling_flags() {
        let p = study_file(
            "schedflags",
            "t:\n  command: sleep-ms 1\n  v: [1, 2, 3]\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let dbs = db.to_str().unwrap();
        // forced lpt with an empty store still runs (unknown costs
        // degrade to admission order); inference flags ride along
        let mut a = args(
            &[p.to_str().unwrap()],
            &[
                ("workers", "2"),
                ("db", dbs),
                ("pack", "lpt"),
                ("timeout-factor", "2.5"),
            ],
        );
        a.flags.push("infer-timeouts".into());
        cmd_run(&a, false).unwrap();
        // "auto" is the default spelling of the coverage-driven mode
        let a = args(&[p.to_str().unwrap()], &[("db", dbs), ("pack", "auto")]);
        cmd_run(&a, true).unwrap();
        let bad =
            args(&[p.to_str().unwrap()], &[("db", dbs), ("pack", "spiral")]);
        assert!(cmd_run(&bad, false).is_err());
        let bad = args(
            &[p.to_str().unwrap()],
            &[("db", dbs), ("timeout-factor", "-1")],
        );
        assert!(cmd_run(&bad, false).is_err());
    }

    #[test]
    fn harvest_compact_rewrites_the_row_log_to_live_rows() {
        let p = study_file(
            "compact",
            "t:\n  command: /bin/sh -c \"echo score=${v}\"\n  v: [1, 2, 3]\n  capture:\n    score: stdout score=([0-9.]+)\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let dbs = db.to_str().unwrap();
        cmd_run(&args(&[p.to_str().unwrap()], &[("db", dbs)]), false).unwrap();
        assert_eq!(crate::results::log_line_count(&db), Some(3));
        // plant a superseded duplicate line: the harvest folds it away
        let log = db.join("results.jsonl");
        let text = std::fs::read_to_string(&log).unwrap();
        let first = text.lines().next().unwrap().to_string();
        std::fs::write(&log, format!("{text}{first}\n")).unwrap();
        assert_eq!(crate::results::log_line_count(&db), Some(4));
        let mut a = args(&[p.to_str().unwrap()], &[("db", dbs)]);
        a.flags.push("compact".into());
        cmd_harvest(&a).unwrap();
        assert_eq!(crate::results::log_line_count(&db), Some(3));
        assert!(!db.join("results.jsonl.tmp").exists());
    }

    #[test]
    fn run_command_fail_fast_then_resume_runs_remainder() {
        let p = study_file(
            "failfastcli",
            // v=3 fails until the unlock marker appears next to work/
            "t:\n  command: /bin/sh -c \"test ${v} -ne 3 || test -f ../unlock\"\n  v: [1, 2, 3, 4, 5]\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let dbs = db.to_str().unwrap();
        let a = args(
            &[p.to_str().unwrap()],
            &[
                ("workers", "1"),
                ("db", dbs),
                ("on-failure", "fail-fast"),
            ],
        );
        // halted: the run errors and tells the user to resume
        let err = cmd_run(&a, false).unwrap_err();
        assert!(err.to_string().contains("fail-fast"), "{err}");
        let ckpt = crate::study::Checkpoint::load(&db).unwrap();
        assert_eq!(ckpt.done_keys.len(), 2); // v=1, v=2 only
        assert!(ckpt.failed_keys.contains("t#2"));

        // unblock v=3 and resume: only the remainder runs
        std::fs::write(db.join("work/unlock"), "").unwrap();
        let mut a = args(&[p.to_str().unwrap()], &[("workers", "1"), ("db", dbs)]);
        a.flags.push("resume".into());
        cmd_run(&a, false).unwrap();
        let ckpt = crate::study::Checkpoint::load(&db).unwrap();
        assert_eq!(ckpt.done_keys.len(), 5);
        assert!(ckpt.failed_keys.is_empty());
    }

    #[test]
    fn run_command_retries_flaky_task_and_status_reports_attempts() {
        let p = study_file(
            "flakycli",
            // first attempt plants a marker and fails; the retry passes
            "t:\n  command: /bin/sh -c \"test -f done_${v} || { touch done_${v}; exit 1; }\"\n  retries: 1\n  v: [1, 2]\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let a = args(
            &[p.to_str().unwrap()],
            &[("workers", "2"), ("db", db.to_str().unwrap())],
        );
        cmd_run(&a, false).unwrap();
        let prov = crate::workflow::Provenance::open(&db).unwrap();
        let attempts = prov.read_attempts().unwrap();
        assert_eq!(attempts.len(), 4); // 2 instances × (1 fail + 1 ok)
        assert_eq!(attempts.iter().filter(|r| r.will_retry).count(), 2);
        // the status view summarizes the attempt log without erroring
        cmd_status(&args(&[db.to_str().unwrap()], &[])).unwrap();
    }

    #[test]
    fn qsim_all_regimes() {
        for regime in ["optimal", "serial", "common"] {
            let a = args(
                &[],
                &[("jobs", "5"), ("regime", regime), ("duration", "10")],
            );
            cmd_qsim(&a).unwrap();
        }
        // grouped form
        let a = args(&[], &[("jobs", "5"), ("nnodes", "2"), ("ppnode", "2")]);
        cmd_qsim(&a).unwrap();
        // bad regime
        let a = args(&[], &[("regime", "zzz")]);
        assert!(cmd_qsim(&a).is_err());
    }

    #[test]
    fn missing_study_file() {
        let a = args(&[], &[]);
        assert!(cmd_run(&a, false).is_err());
        assert!(cmd_validate(&a).is_err());
    }

    #[test]
    fn status_command_reads_db() {
        let p = study_file("status", "t:\n  command: sleep-ms 0\n  v: [1, 2]\n");
        let db = p.parent().unwrap().join(".papas");
        let run_args = args(
            &[p.to_str().unwrap()],
            &[("workers", "1"), ("db", db.to_str().unwrap())],
        );
        cmd_run(&run_args, false).unwrap();
        let mut st = args(&[db.to_str().unwrap()], &[]);
        cmd_status(&st).unwrap();
        st.flags.push("gantt".into());
        cmd_status(&st).unwrap();
        // nonexistent db errors
        assert!(cmd_status(&args(&["/no/such/db"], &[])).is_err());
    }

    #[test]
    fn harvest_query_report_commands() {
        let p = study_file(
            "results",
            // score = 10×v, plus a per-instance output file
            "t:\n  command: /bin/sh -c \"echo score=${v}0; printf 'sum %s0\\n' ${v} > out.txt\"\n  v: [1, 2, 3]\n  capture:\n    score: stdout score=([0-9.]+)\n    fsum: file out\\.txt sum ([0-9.]+)\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let dbs = db.to_str().unwrap();
        cmd_run(&args(&[p.to_str().unwrap()], &[("db", dbs)]), false).unwrap();
        // live capture already produced the store; harvest rebuilds it
        assert!(db.join("results.jsonl").exists());
        cmd_harvest(&args(&[p.to_str().unwrap()], &[("db", dbs)])).unwrap();
        assert!(db.join("results.bin").exists());

        // queries execute in every format, grouped and flat
        for (opts, _) in [
            (vec![("db", dbs), ("where", "v==2"), ("format", "csv")], 1),
            (vec![("db", dbs), ("by", "v"), ("metric", "score")], 3),
            (vec![("db", dbs), ("format", "json")], 3),
            (vec![("db", dbs), ("run", "ALL"), ("format", "csv")], 3),
            (vec![("db", dbs), ("run", "0"), ("by", "v")], 3),
            (
                vec![
                    ("db", dbs),
                    ("sort", "score"),
                    ("top", "2"),
                    ("format", "table"),
                ],
                2,
            ),
        ] {
            let a = args(&[p.to_str().unwrap()], &opts);
            cmd_query(&a).unwrap();
        }
        // bad clauses error cleanly
        assert!(cmd_query(&args(
            &[p.to_str().unwrap()],
            &[("db", dbs), ("where", "ghost==1")]
        ))
        .is_err());
        assert!(cmd_query(&args(
            &[p.to_str().unwrap()],
            &[("db", dbs), ("run", "newest")]
        ))
        .is_err());

        // report with a baseline over the captured metric
        cmd_report(&args(
            &[p.to_str().unwrap()],
            &[("db", dbs), ("metric", "score"), ("by", "v"), ("baseline", "v=1")],
        ))
        .unwrap();
        cmd_report(&args(
            &[p.to_str().unwrap()],
            &[("db", dbs), ("metric", "score"), ("by", "v"), ("format", "json")],
        ))
        .unwrap();
        assert!(cmd_report(&args(&[p.to_str().unwrap()], &[("db", dbs)]))
            .is_err()); // --by required
    }

    #[test]
    fn search_command_runs_rounds_and_writes_the_ledger() {
        let p = study_file(
            "search",
            "t:\n  command: sleep-ms ${v}\n  v: [1, 2, 3, 4]\n  search:\n    objective: minimize wall_time\n    strategy: random\n    rounds: 2\n    budget: 2\n    seed: 1\n",
        );
        let db = p.parent().unwrap().join(".papas");
        let dbs = db.to_str().unwrap();
        let a = args(&[p.to_str().unwrap()], &[("db", dbs), ("workers", "2")]);
        cmd_search(&a).unwrap();
        assert!(db.join("search.jsonl").exists());
        assert!(db.join("results.bin").exists());
        // resume with a higher round cap continues the same search
        let mut a = args(&[p.to_str().unwrap()], &[("db", dbs), ("rounds", "3")]);
        a.flags.push("resume".into());
        cmd_search(&a).unwrap();
        // an unserveable objective errors before running anything
        let a = args(
            &[p.to_str().unwrap()],
            &[("db", dbs), ("objective", "minimize ghost")],
        );
        assert!(cmd_search(&a).is_err());
        // a malformed strategy flag errors at parse time
        let a = args(&[p.to_str().unwrap()], &[("db", dbs), ("strategy", "zzz")]);
        assert!(cmd_search(&a).is_err());
    }

    #[test]
    fn status_format_json_is_machine_readable() {
        let p = study_file(
            "statusjson",
            "t:\n  command: sleep-ms 0\n  v: [1, 2]\n",
        );
        let db = p.parent().unwrap().join(".papas");
        cmd_run(
            &args(&[p.to_str().unwrap()], &[("db", db.to_str().unwrap())]),
            false,
        )
        .unwrap();
        // text and json both succeed; bad format errors
        cmd_status(&args(&[db.to_str().unwrap()], &[])).unwrap();
        cmd_status(&args(&[db.to_str().unwrap()], &[("format", "json")]))
            .unwrap();
        assert!(cmd_status(&args(
            &[db.to_str().unwrap()],
            &[("format", "yaml")]
        ))
        .is_err());
    }

    #[test]
    fn aggregate_command() {
        let p = study_file(
            "agg",
            "t:\n  command: /bin/sh -c \"printf 'a,b\\n1,${v}\\n' > o_${v}.csv\"\n  v: [7, 8]\n",
        );
        let dir = p.parent().unwrap();
        let db = dir.join(".papas");
        cmd_run(
            &args(&[p.to_str().unwrap()], &[("db", db.to_str().unwrap())]),
            false,
        )
        .unwrap();
        let out = dir.join("merged.csv");
        let a = args(
            &[p.to_str().unwrap()],
            &[
                ("db", db.to_str().unwrap()),
                ("pattern", r"^o_.*\.csv$"),
                ("out", out.to_str().unwrap()),
            ],
        );
        cmd_aggregate(&a).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(text.starts_with("instance,combo,a,b"), "{text}");
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    fn dax_command() {
        let p = study_file(
            "dax",
            "a:\n  command: gen out.bin\n  outfiles:\n    o: out.bin\nb:\n  command: use out.bin\n  after: a\n  infiles:\n    i: out.bin\n",
        );
        let a = args(&[p.to_str().unwrap()], &[]);
        cmd_dax(&a).unwrap();
        let bad = args(&[p.to_str().unwrap()], &[("instance", "99")]);
        assert!(cmd_dax(&bad).is_err());
    }
}
