== input yaml
job:
  command: sleep-ms ${ms}
  timeout: 1
  ms: [1]
== expect
ok: tasks=1 params=1 combinations=1 instances=1
warning: task 'job': timeout applies to subprocess commands only; builtin 'sleep-ms' runs in-process and cannot be killed
