== input yaml
matmul:
  name: Matrix multiply scaling study
  environ:
    OMP_NUM_THREADS:
      - 1:4
  args:
    size:
      - 16:*2:128
  command: matmul ${args:size} out_${args:size}_${environ:OMP_NUM_THREADS}.txt
== expect
ok: tasks=1 params=2 combinations=16 instances=16
