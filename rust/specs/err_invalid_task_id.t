== input yaml
"my task":
  command: echo hi
== expect
error: invalid workflow description: invalid task id 'my task'
