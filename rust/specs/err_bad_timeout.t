== input yaml
hello:
  command: echo hi
  timeout: soon
== expect
error: invalid workflow description: task 'hello': timeout must be a number of seconds
