== input yaml
hello:
  command: echo hi
  "bad key": 1
== expect
error: invalid workflow description: task 'hello': invalid keyword 'bad key'
