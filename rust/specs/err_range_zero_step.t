== input yaml
sweep:
  command: echo ${n}
  n: 1:0:5
== expect
error: invalid workflow description: range step is zero: 1:0:5
