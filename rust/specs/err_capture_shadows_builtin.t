== input yaml
trial:
  command: run
  capture:
    wall_time: stdout t=([0-9.]+)
== expect
error: invalid workflow description: task 'trial': capture metric 'wall_time' shadows a built-in result column (wall_time, attempts, exit_code, exit_class) — built-ins are always captured and need no declaration
