== input yaml
- one
- two
== expect
error: invalid workflow description: top level must be a mapping of task sections
