== input yaml
bench:
  command: run ${alpha} ${beta} ${gamma}
  alpha: [1, 2, 3]
  beta: [x, y, z]
  gamma: [10, 20]
  fixed: [alpha, beta]
== expect
ok: tasks=1 params=3 combinations=6 instances=6
