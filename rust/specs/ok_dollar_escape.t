== input yaml
shell:
  command: echo $$HOME ${n}
  n: [1, 2]
== expect
ok: tasks=1 params=1 combinations=2 instances=2
