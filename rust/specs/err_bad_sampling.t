== input yaml
sweep:
  command: run
  sampling: sobol 4
== expect
error: parameter space error: bad sampling 'sobol 4'; sampling expects 'uniform N' or 'random N [seed S]'
