== input yaml
a:
  command: one
  on_failure: continue
b:
  command: two
  on_failure: fail-fast
== expect
ok: tasks=2 params=0 combinations=1 instances=1
warning: task 'b' declares on_failure 'fail-fast' but task 'a' already set the study policy to 'continue'; the first declaration wins
