== input yaml
big:
  command: run
  nnodes: 0
== expect
error: invalid workflow description: task 'big': nnodes/ppnode must be positive
