== input ini
[a.b.c]
== expect
error: parse error at line 1, col 1: invalid section path 'a.b.c' (at most one dot)
