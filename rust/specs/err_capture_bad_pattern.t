== input yaml
trial:
  command: run
  capture:
    m: stdout (?P<v>[0-9]+)
== expect
error: invalid workflow description: task 'trial': capture 'm': bad pattern '(?P<v>[0-9]+)': regex parse error: only (?:...) groups are supported
