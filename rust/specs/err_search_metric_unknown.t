== input yaml
tune:
  command: run
  search:
    objective: minimize latency
== expect
error: invalid workflow description: task 'tune': search objective metric 'latency' is neither a built-in result column nor declared by any capture: block
