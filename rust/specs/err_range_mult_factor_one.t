== input yaml
sweep:
  command: echo ${n}
  n: 2:*1:8
== expect
error: invalid workflow description: multiplicative range factor must be positive and != 1, got 1
