== input yaml
hello:
  command: echo one
  command: echo two
== expect
error: parse error at line 3, col 3: duplicate key 'command'
