== input yaml
a:
  command: stage-in
b:
  command: compute ${n}
  n: 1:3
  after: a
c:
  command: collate
  after: a
d:
  command: reduce-all
  after: [b, c]
== expect
ok: tasks=4 params=1 combinations=3 instances=3
