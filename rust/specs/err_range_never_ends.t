== input yaml
sweep:
  command: echo ${n}
  n: 5:1:1
== expect
error: invalid workflow description: range 5:1:1 never reaches its end
