== input yaml
hello:
  command: echo ${threads}
  threads: []
== expect
error: invalid workflow description: task 'hello': parameter 'threads' has no values
