== input yaml
hello:
  command: echo hi
  args:
    size:
      deep: 1
== expect
error: invalid workflow description: task 'hello': parameter 'size' nests deeper than two levels (the WDL allows at most two)
