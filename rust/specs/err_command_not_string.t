== input yaml
hello:
  command: [echo, hi]
== expect
error: invalid workflow description: task 'hello': command must be a string
