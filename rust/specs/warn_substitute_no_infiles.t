== input yaml
patch:
  command: process input.txt
  substitute:
    NN: [1, 2]
== expect
ok: tasks=1 params=1 combinations=2 instances=2
warning: task 'patch': substitute without infiles has no effect
