== input yaml
queued:
  command: run-it
  batch: slurm
== expect
ok: tasks=1 params=0 combinations=1 instances=1
warning: task 'queued': batch system set but parallel=local; the batch directive only applies to cluster submission
