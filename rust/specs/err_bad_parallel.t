== input yaml
hello:
  command: echo hi
  parallel: cloud
== expect
error: invalid workflow description: unknown parallel mode 'cloud' (expected local, ssh, or mpi)
