== input json
hello
== expect
error: parse error at line 1, col 1: unexpected character 'h'
