== input ini
[hello]
command = echo ${args:size}

[hello.args]
size = 1:3
== expect
ok: tasks=1 params=1 combinations=3 instances=3
