== input yaml
hello:
  command echo hi
== expect
error: parse error at line 2, col 3: expected 'key: value', found 'command echo hi'
