== input yaml
a:
  command: step-a
  after: c
b:
  command: step-b
  after: a
c:
  command: step-c
  after: b
== expect
error: invalid workflow description: dependency cycle among tasks ["a", "b", "c"]
