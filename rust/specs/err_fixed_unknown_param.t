== input yaml
grid:
  command: run ${x}
  x: [1, 2]
  fixed: [x, y]
== expect
error: invalid workflow description: task 'grid': fixed clause references unknown parameter 'y'
