== input yaml
a:
  command: one
  on_failure: fail-fast
  retries: 2
== expect
ok: tasks=1 params=0 combinations=1 instances=1
warning: task 'a': retries have no effect under on_failure fail-fast
