== input yaml
hello:
  command: echo hi
  - stray
== expect
error: parse error at line 3, col 3: sequence item in mapping context
