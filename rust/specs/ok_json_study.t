== input json
{"hello": {"command": "echo ${n}", "n": [1, 2, 3]}}
== expect
ok: tasks=1 params=1 combinations=3 instances=3
