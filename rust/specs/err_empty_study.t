== input yaml
# a comment-only document compiles to an empty mapping
== expect
error: invalid workflow description: study has no task sections
