== input yaml
b:
  command: echo hi
  after: ghost
== expect
error: invalid workflow description: task 'b' depends on unknown task 'ghost'
