== input ini
[hello
== expect
error: parse error at line 1, col 1: unterminated section header
