== input yaml
tune:
  command: run
  search:
    rounds: 0
== expect
error: invalid workflow description: task 'tune': search rounds must be positive
