== input yaml
hello:
  threads: [1, 2]
== expect
error: invalid workflow description: task 'hello' has no command (a task is identified by the command keyword)
