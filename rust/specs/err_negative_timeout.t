== input yaml
hello:
  command: echo hi
  timeout: -3
== expect
error: invalid workflow description: task 'hello': timeout must be positive, got '-3'
