== input yaml
hello:
  command: echo hi
  retries: many
== expect
error: invalid workflow description: task 'hello': 'retries' must be a positive integer
