== input yaml
a:
  command: echo hi
  after: a
== expect
error: invalid workflow description: task 'a' depends on itself
