== input yaml
greet:
  command: echo ${msg}
  msg: [hello ${who}, bye ${who}]
  who: [world, moon]
== expect
ok: tasks=1 params=2 combinations=4 instances=4
