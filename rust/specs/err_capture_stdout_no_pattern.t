== input yaml
trial:
  command: run
  capture:
    m: stdout
== expect
error: invalid workflow description: task 'trial': capture 'm': `stdout` needs a pattern (capture: m: stdout PATTERN)
