== input yaml
sub:
  command: process data.txt
  infiles:
    data: data.txt
  substitute:
    (?P<x>.+): [fast, slow]
== expect
error: invalid workflow description: task 'sub': substitute pattern '(?P<x>.+)' is not a valid regular expression: regex parse error: only (?:...) groups are supported
