== input yaml
remote:
  command: run-it
  parallel: ssh
== expect
ok: tasks=1 params=0 combinations=1 instances=1
warning: task 'remote': parallel=ssh without hosts; defaulting to localhost workers
