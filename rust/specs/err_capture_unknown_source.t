== input yaml
trial:
  command: run
  capture:
    m: grep foo
== expect
error: invalid workflow description: task 'trial': capture 'm': unknown source 'grep' (expected `stdout PATTERN` or `file NAME_RE [PATTERN]`)
