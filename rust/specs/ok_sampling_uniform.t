== input yaml
sweep:
  command: sim ${p} ${q}
  p: 1:10
  q: 1:10
  sampling: uniform 5
== expect
ok: tasks=1 params=2 combinations=100 instances=5
