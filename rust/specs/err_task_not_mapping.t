== input yaml
hello: just a string
== expect
error: invalid workflow description: task 'hello' must be a mapping of keywords
