== input yaml
solo:
  command: echo ${nope}
== expect
error: invalid workflow description: task 'solo': command references '${nope}' which no parameter provides
