== input yaml
hello:
  command: echo hi
  on_failure: explode
== expect
error: invalid workflow description: task 'hello': on_failure: unknown failure policy 'explode' (expected fail-fast, continue, or retry-budget N)
