== input yaml
tune:
  command: run
  search:
    objective: sideways wall_time
== expect
error: invalid workflow description: task 'tune': parameter space error: bad objective direction 'sideways'; objective expects 'minimize METRIC' or 'maximize METRIC'
