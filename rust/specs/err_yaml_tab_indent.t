== input yaml
hello:
 	command: echo hi
== expect
error: parse error at line 2, col 2: tab after spaces in indentation
