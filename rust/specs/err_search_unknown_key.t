== input yaml
tune:
  command: run
  search:
    budgget: 5
== expect
error: invalid workflow description: task 'tune': unknown search key 'budgget' (expected objective, strategy, rounds, budget, or seed)
