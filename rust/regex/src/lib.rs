//! A small, dependency-free stand-in for the `regex` crate, providing the
//! subset of its API that PaPaS uses: `Regex::new`, `is_match`,
//! `replace_all`, and `captures`. The real crate is unavailable offline,
//! so this implements a classic Thompson-NFA ("Pike VM") engine — linear
//! time in `pattern × text`, no backtracking blowups — for the boolean /
//! replacement paths, plus a bounded backtracking matcher for submatch
//! extraction (`captures`), which the Pike VM cannot report.
//!
//! Supported syntax: literals, `.`, `*`, `+`, `?`, alternation `|`,
//! capturing groups `(...)` and non-capturing `(?:...)` (replacements
//! are literal either way), character classes `[...]` with ranges and
//! `^` negation, the Perl classes `\d \D \s \S \w \W`, anchors `^` and
//! `$`, and `\`-escaped metacharacters. `is_match`/`replace_all` are
//! leftmost-longest; `captures` is leftmost-greedy (the backtracker's
//! natural order), which agrees on every anchored or unambiguous
//! pattern the engine serves.

use std::borrow::Cow;
use std::fmt;

/// Regex compilation error (message-only, `Display`-compatible with the
/// real crate's error type for the purposes of `format!("{e}")`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "regex parse error: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// A compiled regular expression.
#[derive(Debug, Clone)]
pub struct Regex {
    prog: Vec<Inst>,
    pattern: String,
    /// Number of capturing groups (slots 2i/2i+1 per group i, 1-based;
    /// slots 0/1 hold the whole match).
    n_groups: usize,
}

// ---------------------------------------------------------------- AST --

#[derive(Debug, Clone)]
enum Node {
    Char(char),
    Any,
    Class { neg: bool, items: Vec<ClassItem> },
    Start,
    End,
    Seq(Vec<Node>),
    Alt(Box<Node>, Box<Node>),
    Repeat { node: Box<Node>, min: u8, unbounded: bool },
    /// Capturing group `(...)`; the index is 1-based (group 0 is the
    /// whole match).
    Group(usize, Box<Node>),
}

#[derive(Debug, Clone)]
enum ClassItem {
    Ch(char),
    Range(char, char),
    Perl(char), // d D s S w W
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
    /// Capturing groups seen so far (assigns 1-based indices in order of
    /// their opening parenthesis, like the real crate).
    n_groups: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn parse_alt(&mut self) -> Result<Node, Error> {
        let mut node = self.parse_seq()?;
        while self.peek() == Some('|') {
            self.bump();
            let rhs = self.parse_seq()?;
            node = Node::Alt(Box::new(node), Box::new(rhs));
        }
        Ok(node)
    }

    fn parse_seq(&mut self) -> Result<Node, Error> {
        let mut items: Vec<Node> = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            let atom = self.parse_atom()?;
            let atom = self.parse_postfix(atom)?;
            items.push(atom);
        }
        Ok(Node::Seq(items))
    }

    fn parse_postfix(&mut self, atom: Node) -> Result<Node, Error> {
        let Some(c) = self.peek() else { return Ok(atom) };
        let (min, unbounded) = match c {
            '*' => (0, true),
            '+' => (1, true),
            '?' => (0, false),
            _ => return Ok(atom),
        };
        self.bump();
        if matches!(atom, Node::Start | Node::End) {
            return Err(Error(format!("nothing to repeat before '{c}'")));
        }
        // a trailing lazy marker (`*?`, `+?`, `??`) is accepted and
        // ignored: the VM is leftmost-longest, so laziness cannot change
        // is_match / replace_all boundaries for the patterns we serve
        if self.peek() == Some('?') {
            self.bump();
        }
        Ok(Node::Repeat { node: Box::new(atom), min, unbounded })
    }

    fn parse_atom(&mut self) -> Result<Node, Error> {
        let c = self.bump().ok_or_else(|| Error("unexpected end".into()))?;
        match c {
            '(' => {
                // `(?:...)` groups only; a bare `(` opens a capturing
                // group and claims the next 1-based group index.
                let capture = if self.peek() == Some('?') {
                    self.bump();
                    if self.peek() == Some(':') {
                        self.bump();
                        false
                    } else {
                        return Err(Error(
                            "only (?:...) groups are supported".into(),
                        ));
                    }
                } else {
                    self.n_groups += 1;
                    true
                };
                let idx = self.n_groups;
                let inner = self.parse_alt()?;
                if self.bump() != Some(')') {
                    return Err(Error("unclosed group '('".into()));
                }
                if capture {
                    Ok(Node::Group(idx, Box::new(inner)))
                } else {
                    Ok(inner)
                }
            }
            '[' => self.parse_class(),
            '.' => Ok(Node::Any),
            '^' => Ok(Node::Start),
            '$' => Ok(Node::End),
            '*' | '+' | '?' => Err(Error(format!("nothing to repeat before '{c}'"))),
            '\\' => self.parse_escape(),
            other => Ok(Node::Char(other)),
        }
    }

    fn parse_escape(&mut self) -> Result<Node, Error> {
        let c = self
            .bump()
            .ok_or_else(|| Error("dangling '\\' at end of pattern".into()))?;
        match c {
            'd' | 'D' | 's' | 'S' | 'w' | 'W' => Ok(Node::Class {
                neg: false,
                items: vec![ClassItem::Perl(c)],
            }),
            'n' => Ok(Node::Char('\n')),
            't' => Ok(Node::Char('\t')),
            'r' => Ok(Node::Char('\r')),
            other => Ok(Node::Char(other)),
        }
    }

    fn parse_class(&mut self) -> Result<Node, Error> {
        let mut neg = false;
        if self.peek() == Some('^') {
            neg = true;
            self.bump();
        }
        let mut items = Vec::new();
        let mut first = true;
        loop {
            let Some(c) = self.bump() else {
                return Err(Error("unclosed character class '['".into()));
            };
            if c == ']' && !first {
                break;
            }
            first = false;
            let lo = if c == '\\' {
                let e = self.bump().ok_or_else(|| {
                    Error("dangling '\\' in character class".into())
                })?;
                match e {
                    'd' | 'D' | 's' | 'S' | 'w' | 'W' => {
                        items.push(ClassItem::Perl(e));
                        continue;
                    }
                    'n' => '\n',
                    't' => '\t',
                    'r' => '\r',
                    other => other,
                }
            } else {
                c
            };
            // range `a-z` (a trailing '-' is a literal)
            if self.peek() == Some('-')
                && self.chars.get(self.pos + 1).is_some_and(|&c| c != ']')
            {
                self.bump(); // '-'
                let hi = self.bump().unwrap();
                let hi = if hi == '\\' {
                    self.bump().ok_or_else(|| {
                        Error("dangling '\\' in character class".into())
                    })?
                } else {
                    hi
                };
                if hi < lo {
                    return Err(Error(format!(
                        "invalid class range '{lo}-{hi}'"
                    )));
                }
                items.push(ClassItem::Range(lo, hi));
            } else {
                items.push(ClassItem::Ch(lo));
            }
        }
        if items.is_empty() {
            return Err(Error("empty character class".into()));
        }
        Ok(Node::Class { neg, items })
    }
}

// ------------------------------------------------------ Thompson NFA --

#[derive(Debug, Clone)]
enum Inst {
    Char(char),
    Any,
    Class { neg: bool, items: Vec<ClassItem> },
    Start,
    End,
    Split(usize, usize),
    Jmp(usize),
    /// Record the current position into a capture slot (group i begins
    /// at slot 2i and ends at 2i+1). An epsilon transition for the Pike
    /// VM; the backtracker records positions.
    Save(usize),
    Match,
}

/// Base step budget of one `captures` call (all start offsets combined;
/// grown linearly for long inputs — see `captures`) against exponential
/// blowup / empty-body star loops; a pattern that exhausts it reports
/// "no match" rather than wedging a worker (metric-extraction patterns
/// are tiny).
const STEP_LIMIT: usize = 1_000_000;

fn class_matches(neg: bool, items: &[ClassItem], c: char) -> bool {
    let hit = items.iter().any(|it| match it {
        ClassItem::Ch(x) => *x == c,
        ClassItem::Range(lo, hi) => *lo <= c && c <= *hi,
        ClassItem::Perl(p) => match p {
            'd' => c.is_ascii_digit(),
            'D' => !c.is_ascii_digit(),
            's' => c.is_whitespace(),
            'S' => !c.is_whitespace(),
            'w' => c.is_alphanumeric() || c == '_',
            'W' => !(c.is_alphanumeric() || c == '_'),
            _ => false,
        },
    });
    hit != neg
}

fn compile(node: &Node, prog: &mut Vec<Inst>) {
    match node {
        Node::Char(c) => prog.push(Inst::Char(*c)),
        Node::Any => prog.push(Inst::Any),
        Node::Class { neg, items } => {
            prog.push(Inst::Class { neg: *neg, items: items.clone() })
        }
        Node::Start => prog.push(Inst::Start),
        Node::End => prog.push(Inst::End),
        Node::Seq(items) => {
            for it in items {
                compile(it, prog);
            }
        }
        Node::Alt(a, b) => {
            let split = prog.len();
            prog.push(Inst::Jmp(0)); // placeholder → Split
            compile(a, prog);
            let jmp = prog.len();
            prog.push(Inst::Jmp(0)); // placeholder → Jmp(end)
            let b_start = prog.len();
            compile(b, prog);
            let end = prog.len();
            prog[split] = Inst::Split(split + 1, b_start);
            prog[jmp] = Inst::Jmp(end);
        }
        Node::Group(idx, inner) => {
            prog.push(Inst::Save(2 * idx));
            compile(inner, prog);
            prog.push(Inst::Save(2 * idx + 1));
        }
        Node::Repeat { node, min, unbounded } => {
            match (*min, *unbounded) {
                (0, false) => {
                    // e? : Split(body, end)
                    let split = prog.len();
                    prog.push(Inst::Jmp(0));
                    compile(node, prog);
                    let end = prog.len();
                    prog[split] = Inst::Split(split + 1, end);
                }
                (0, true) => {
                    // e* : L: Split(body, end); body; Jmp(L)
                    let l = prog.len();
                    prog.push(Inst::Jmp(0));
                    compile(node, prog);
                    prog.push(Inst::Jmp(l));
                    let end = prog.len();
                    prog[l] = Inst::Split(l + 1, end);
                }
                (_, true) => {
                    // e+ : L: body; Split(L, end)
                    let l = prog.len();
                    compile(node, prog);
                    let split = prog.len();
                    prog.push(Inst::Split(l, split + 1));
                }
                (_, false) => unreachable!("parser emits 0/1-min repeats"),
            }
        }
    }
}

impl Regex {
    /// Compile a pattern.
    pub fn new(pattern: &str) -> Result<Regex, Error> {
        let mut p =
            Parser { chars: pattern.chars().collect(), pos: 0, n_groups: 0 };
        let ast = p.parse_alt()?;
        if p.pos != p.chars.len() {
            // only reachable via an unbalanced ')'
            return Err(Error("unmatched ')'".into()));
        }
        let mut prog = Vec::new();
        compile(&ast, &mut prog);
        prog.push(Inst::Match);
        Ok(Regex { prog, pattern: pattern.to_string(), n_groups: p.n_groups })
    }

    /// Number of capture groups including the implicit whole-match
    /// group 0 — always ≥ 1, matching the real crate's
    /// `Regex::captures_len` contract so callers survive a swap to the
    /// real dependency.
    pub fn captures_len(&self) -> usize {
        self.n_groups + 1
    }

    /// The source pattern.
    pub fn as_str(&self) -> &str {
        &self.pattern
    }

    /// True when the pattern matches anywhere in `text`.
    pub fn is_match(&self, text: &str) -> bool {
        let chars: Vec<char> = text.chars().collect();
        (0..=chars.len()).any(|start| self.match_at(&chars, start).is_some())
    }

    /// Replace every non-overlapping match with `rep` (literal — `$N`
    /// capture references are not supported by this stand-in).
    pub fn replace_all<'t>(&self, text: &'t str, rep: &str) -> Cow<'t, str> {
        let chars: Vec<char> = text.chars().collect();
        let mut out = String::new();
        let mut pos = 0usize;
        let mut changed = false;
        while pos <= chars.len() {
            match self.match_at(&chars, pos) {
                Some(end) => {
                    changed = true;
                    out.push_str(rep);
                    if end == pos {
                        // empty match: emit the next char and advance
                        if pos < chars.len() {
                            out.push(chars[pos]);
                        }
                        pos += 1;
                    } else {
                        pos = end;
                    }
                }
                None => {
                    if pos < chars.len() {
                        out.push(chars[pos]);
                    }
                    pos += 1;
                }
            }
        }
        if changed {
            Cow::Owned(out)
        } else {
            Cow::Borrowed(text)
        }
    }

    /// Pike-VM simulation from a fixed start offset; returns the longest
    /// match end (in chars) or None.
    fn match_at(&self, chars: &[char], start: usize) -> Option<usize> {
        let n = self.prog.len();
        let mut current: Vec<usize> = Vec::with_capacity(n);
        let mut on_current = vec![false; n];
        let mut best: Option<usize> = None;

        let mut add = |list: &mut Vec<usize>,
                       on: &mut Vec<bool>,
                       pc: usize,
                       at: usize,
                       text_len: usize,
                       best: &mut Option<usize>| {
            // iterative epsilon closure
            let mut stack = vec![pc];
            while let Some(pc) = stack.pop() {
                if on[pc] {
                    continue;
                }
                on[pc] = true;
                match &self.prog[pc] {
                    Inst::Split(a, b) => {
                        stack.push(*a);
                        stack.push(*b);
                    }
                    Inst::Jmp(t) => stack.push(*t),
                    // Position bookkeeping is a no-op for the boolean VM.
                    Inst::Save(_) => stack.push(pc + 1),
                    Inst::Start => {
                        if at == 0 {
                            stack.push(pc + 1);
                        }
                    }
                    Inst::End => {
                        if at == text_len {
                            stack.push(pc + 1);
                        }
                    }
                    Inst::Match => {
                        *best = Some(match *best {
                            Some(b) => b.max(at),
                            None => at,
                        });
                    }
                    _ => list.push(pc),
                }
            }
        };

        add(&mut current, &mut on_current, 0, start, chars.len(), &mut best);
        let mut at = start;
        while at < chars.len() && !current.is_empty() {
            let c = chars[at];
            let mut next: Vec<usize> = Vec::with_capacity(n);
            let mut on_next = vec![false; n];
            for &pc in &current {
                let consumed = match &self.prog[pc] {
                    Inst::Char(x) => *x == c,
                    Inst::Any => true,
                    Inst::Class { neg, items } => class_matches(*neg, items, c),
                    _ => false,
                };
                if consumed {
                    add(
                        &mut next,
                        &mut on_next,
                        pc + 1,
                        at + 1,
                        chars.len(),
                        &mut best,
                    );
                }
            }
            current = next;
            on_current = on_next;
            at += 1;
        }
        let _ = on_current;
        best
    }

    /// Leftmost match with submatch extraction: the first start offset
    /// (in chars) at which the backtracking matcher succeeds. Returns
    /// `None` when nothing matches (or when a pathological pattern
    /// exhausts the step budget — this is a stand-in, not RE2).
    pub fn captures<'t>(&self, text: &'t str) -> Option<Captures<'t>> {
        let chars: Vec<char> = text.chars().collect();
        // char index → byte offset, so slots slice the original &str.
        let mut byte_of: Vec<usize> = Vec::with_capacity(chars.len() + 1);
        let mut b = 0usize;
        for c in &chars {
            byte_of.push(b);
            b += c.len_utf8();
        }
        byte_of.push(b);
        // One step budget shared across every start offset — per-start
        // budgets would multiply by the text length and a pathological
        // pattern could stall a caller for minutes. Scaled with the
        // input so that merely *scanning* a long text (≥1 step per
        // failing start) can never exhaust it before a late match.
        let limit = STEP_LIMIT.max(8 * (chars.len() + 1));
        let mut steps = 0usize;
        for start in 0..=chars.len() {
            if let Some(slots) =
                self.backtrack_at(&chars, start, &mut steps, limit)
            {
                return Some(Captures { text, slots, byte_of });
            }
            if steps > limit {
                return None;
            }
        }
        None
    }

    /// Iterative backtracking VM from a fixed start offset. Greedy
    /// (`Split` prefers its first branch, which the compiler points at
    /// the repeat body), with an explicit choice-point stack and a save
    /// trail so group slots rewind on backtrack. `steps` is the caller's
    /// running budget (capped at `limit`), shared across start offsets.
    fn backtrack_at(
        &self,
        chars: &[char],
        start: usize,
        steps: &mut usize,
        limit: usize,
    ) -> Option<Vec<Option<usize>>> {
        struct Choice {
            pc: usize,
            at: usize,
            trail_len: usize,
        }

        let mut slots: Vec<Option<usize>> = vec![None; 2 * (self.n_groups + 1)];
        slots[0] = Some(start);
        let mut trail: Vec<(usize, Option<usize>)> = Vec::new();
        let mut alts: Vec<Choice> = Vec::new();
        let (mut pc, mut at) = (0usize, start);
        loop {
            *steps += 1;
            if *steps > limit {
                return None;
            }
            let ok = match &self.prog[pc] {
                Inst::Char(x) => {
                    if at < chars.len() && chars[at] == *x {
                        at += 1;
                        pc += 1;
                        true
                    } else {
                        false
                    }
                }
                Inst::Any => {
                    if at < chars.len() {
                        at += 1;
                        pc += 1;
                        true
                    } else {
                        false
                    }
                }
                Inst::Class { neg, items } => {
                    if at < chars.len() && class_matches(*neg, items, chars[at]) {
                        at += 1;
                        pc += 1;
                        true
                    } else {
                        false
                    }
                }
                Inst::Start => {
                    if at == 0 {
                        pc += 1;
                        true
                    } else {
                        false
                    }
                }
                Inst::End => {
                    if at == chars.len() {
                        pc += 1;
                        true
                    } else {
                        false
                    }
                }
                Inst::Split(a, b) => {
                    alts.push(Choice { pc: *b, at, trail_len: trail.len() });
                    pc = *a;
                    true
                }
                Inst::Jmp(t) => {
                    pc = *t;
                    true
                }
                Inst::Save(slot) => {
                    trail.push((*slot, slots[*slot]));
                    slots[*slot] = Some(at);
                    pc += 1;
                    true
                }
                Inst::Match => {
                    slots[1] = Some(at);
                    return Some(slots);
                }
            };
            if !ok {
                let c = alts.pop()?;
                while trail.len() > c.trail_len {
                    let (slot, old) = trail.pop().expect("trail underflow");
                    slots[slot] = old;
                }
                pc = c.pc;
                at = c.at;
            }
        }
    }
}

/// One submatch: a resolved slice of the searched text.
#[derive(Debug, Clone, Copy)]
pub struct Match<'t> {
    text: &'t str,
    start: usize,
    end: usize,
}

impl<'t> Match<'t> {
    /// The matched text.
    pub fn as_str(&self) -> &'t str {
        &self.text[self.start..self.end]
    }

    /// Byte offset of the match start.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Byte offset just past the match end.
    pub fn end(&self) -> usize {
        self.end
    }
}

/// The capture groups of one successful match. Group 0 is the whole
/// match; groups that did not participate return `None`.
#[derive(Debug, Clone)]
pub struct Captures<'t> {
    text: &'t str,
    /// Char positions: slot 2i = group i start, 2i+1 = group i end.
    slots: Vec<Option<usize>>,
    /// Char index → byte offset (one extra entry for the text end).
    byte_of: Vec<usize>,
}

impl<'t> Captures<'t> {
    /// The i-th group (0 = whole match).
    pub fn get(&self, i: usize) -> Option<Match<'t>> {
        let (s, e) = (*self.slots.get(2 * i)?, *self.slots.get(2 * i + 1)?);
        match (s, e) {
            (Some(s), Some(e)) => Some(Match {
                text: self.text,
                start: self.byte_of[s],
                end: self.byte_of[e],
            }),
            _ => None,
        }
    }

    /// Number of groups including the implicit whole-match group 0.
    pub fn len(&self) -> usize {
        self.slots.len() / 2
    }

    /// Never empty: group 0 always exists on a successful match.
    pub fn is_empty(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_and_anchors() {
        let re = Regex::new("^o_.*\\.csv$").unwrap();
        assert!(re.is_match("o_7.csv"));
        assert!(!re.is_match("x_o_7.csv"));
        assert!(!re.is_match("o_7.csvx"));
        assert!(Regex::new(".*\\.csv$").unwrap().is_match("anything.csv"));
    }

    #[test]
    fn classes_and_perl_escapes() {
        let re = Regex::new("beta=\"[0-9.]+\"").unwrap();
        assert!(re.is_match("x beta=\"0.25\" y"));
        assert!(!re.is_match("beta=\"\""));
        let re = Regex::new("beta=\\S+").unwrap();
        assert!(re.is_match("beta=0.5"));
        assert!(!re.is_match("beta= 0.5"));
        assert!(Regex::new("[^a-z]").unwrap().is_match("A"));
        assert!(!Regex::new("[^a-z]").unwrap().is_match("abc"));
        assert!(Regex::new("\\d+").unwrap().is_match("a42b"));
    }

    #[test]
    fn quantifiers_and_alternation() {
        let re = Regex::new("ab?c").unwrap();
        assert!(re.is_match("ac"));
        assert!(re.is_match("abc"));
        assert!(!re.is_match("abbc"));
        let re = Regex::new("(cat|dog)s?").unwrap();
        assert!(re.is_match("cats"));
        assert!(re.is_match("dog"));
        assert!(!re.is_match("cow"));
    }

    #[test]
    fn replace_all_is_greedy_and_nonoverlapping() {
        let re = Regex::new("beta=\"[0-9.]+\"").unwrap();
        let out = re.replace_all("<run beta=\"0.5\" steps=\"100\"/>", "beta=\"0.9\"");
        assert_eq!(out, "<run beta=\"0.9\" steps=\"100\"/>");
        let re = Regex::new("a+").unwrap();
        assert_eq!(re.replace_all("aa b aaa", "X"), "X b X");
        // no match borrows the input
        let re = Regex::new("zzz").unwrap();
        assert!(matches!(re.replace_all("abc", "X"), Cow::Borrowed(_)));
    }

    #[test]
    fn errors_are_reported() {
        assert!(Regex::new("[").is_err());
        assert!(Regex::new("(unclosed").is_err());
        assert!(Regex::new("a)").is_err());
        assert!(Regex::new("*x").is_err());
        assert!(Regex::new("x\\").is_err());
        assert!(Regex::new("[z-a]").is_err());
        let e = Regex::new("[").unwrap_err();
        assert!(format!("{e}").contains("regex parse error"));
    }

    #[test]
    fn leftmost_longest() {
        let re = Regex::new("a|ab").unwrap();
        // longest at the leftmost position
        assert_eq!(re.replace_all("ab", "X"), "X");
    }

    #[test]
    fn empty_pattern_matches_everywhere() {
        let re = Regex::new("").unwrap();
        assert!(re.is_match("abc"));
        assert_eq!(re.replace_all("ab", "-"), "-a-b-");
    }

    #[test]
    fn captures_extract_groups() {
        let re = Regex::new(r"checksum=([-+0-9.eE]+)").unwrap();
        assert_eq!(re.captures_len(), 2); // group 0 + one explicit group
        let c = re
            .captures("matmul n=64 threads=2 checksum=1.234560e3 done")
            .unwrap();
        assert_eq!(c.get(0).unwrap().as_str(), "checksum=1.234560e3");
        assert_eq!(c.get(1).unwrap().as_str(), "1.234560e3");
        assert_eq!(c.len(), 2);
        assert!(re.captures("no metric here").is_none());
    }

    #[test]
    fn captures_multiple_and_nested_groups() {
        let re = Regex::new(r"(\w+)=(\d+(\.\d+)?)").unwrap();
        assert_eq!(re.captures_len(), 4);
        let c = re.captures("x time=12.75 y").unwrap();
        assert_eq!(c.get(1).unwrap().as_str(), "time");
        assert_eq!(c.get(2).unwrap().as_str(), "12.75");
        assert_eq!(c.get(3).unwrap().as_str(), ".75");
        // optional group absent → None, others still report
        let c = re.captures("n=42").unwrap();
        assert_eq!(c.get(2).unwrap().as_str(), "42");
        assert!(c.get(3).is_none());
        assert!(c.get(9).is_none());
    }

    #[test]
    fn captures_is_leftmost() {
        let re = Regex::new(r"(\d+)").unwrap();
        let c = re.captures("a 10 b 20").unwrap();
        assert_eq!(c.get(1).unwrap().as_str(), "10");
    }

    #[test]
    fn captures_alternation_and_anchors() {
        let re = Regex::new(r"^(cat|dog)s?$").unwrap();
        let c = re.captures("dogs").unwrap();
        assert_eq!(c.get(1).unwrap().as_str(), "dog");
        assert!(re.captures("catfish").is_none());
        // non-capturing groups claim no slot
        let re = Regex::new(r"(?:val|v)=(\d+)").unwrap();
        assert_eq!(re.captures_len(), 2);
        let c = re.captures("v=7").unwrap();
        assert_eq!(c.get(1).unwrap().as_str(), "7");
    }

    #[test]
    fn captures_greedy_with_backtracking() {
        let re = Regex::new(r"(.*)=(\d+)").unwrap();
        // greedy .* must back off to let the digits match
        let c = re.captures("a=b=42").unwrap();
        assert_eq!(c.get(1).unwrap().as_str(), "a=b");
        assert_eq!(c.get(2).unwrap().as_str(), "42");
    }

    #[test]
    fn captures_multibyte_offsets() {
        let re = Regex::new(r"€(\d+)").unwrap();
        let c = re.captures("cost €42 total").unwrap();
        assert_eq!(c.get(0).unwrap().as_str(), "€42");
        assert_eq!(c.get(1).unwrap().as_str(), "42");
        assert_eq!(&"cost €42 total"[c.get(1).unwrap().start()..c.get(1).unwrap().end()], "42");
    }

    #[test]
    fn capturing_groups_leave_boolean_paths_unchanged() {
        // Save instructions are epsilon transitions for the Pike VM.
        let re = Regex::new(r"(a+)(b+)").unwrap();
        assert!(re.is_match("xxaabbyy"));
        assert_eq!(re.replace_all("aab ab", "X"), "X X");
    }
}
