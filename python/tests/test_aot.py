"""AOT pipeline: the artifacts directory is complete and self-consistent,
and re-lowering reproduces the committed HLO (build determinism).

The actual load-and-execute round trip through PJRT happens on the Rust
side (rust/tests/runtime_hlo.rs compares HLO-artifact numerics against
the native implementation); here we validate the build-time half.
"""

import hashlib
import json
import os

from compile import aot

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        return json.load(f)


def test_manifest_inventory():
    m = manifest()
    names = set(m["artifacts"])
    for n in aot.MATMUL_SIZES:
        assert f"matmul_{n}" in names
    for p, h, t in aot.ABM_VARIANTS:
        assert f"abm_p{p}_h{h}_t{t}" in names


def test_files_exist_and_hashes_match():
    m = manifest()
    for name, meta in m["artifacts"].items():
        path = os.path.join(ARTIFACTS, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert len(text) == meta["hlo_bytes"], name
        assert hashlib.sha256(text.encode()).hexdigest() == meta["sha256"], name
        # HLO text sanity: an entry computation with parameters
        assert "ENTRY" in text, name
        assert "parameter(0)" in text, name


def test_matmul_metadata():
    m = manifest()
    for n in aot.MATMUL_SIZES:
        meta = m["artifacts"][f"matmul_{n}"]
        assert meta["kind"] == "matmul"
        assert meta["size"] == n
        assert meta["flops"] == 2 * n**3
        assert meta["inputs"] == [
            {"shape": [n, n], "dtype": "f32"},
            {"shape": [n, n], "dtype": "f32"},
        ]
        assert meta["outputs"][0]["shape"] == [n, n]
        est = meta["tpu_estimate"]
        assert 0.0 < est["mxu_utilization"] <= 1.0
        assert est["vmem_bytes"] < 16 * 2**20


def test_abm_metadata():
    m = manifest()
    for p, h, t in aot.ABM_VARIANTS:
        meta = m["artifacts"][f"abm_p{p}_h{h}_t{t}"]
        assert meta["kind"] == "abm"
        assert meta["n_patients"] == p
        assert meta["n_hcw"] == h
        assert meta["n_steps"] == t
        assert meta["inputs"][0] == {"shape": [], "dtype": "i32"}
        assert meta["inputs"][1]["shape"] == [8]
        assert meta["outputs"][0]["shape"] == [t, 6]
        assert meta["param_names"][0] == "beta"
        assert meta["metric_names"][1] == "n_colonized"


def test_relower_is_deterministic():
    """Lowering the same function again yields byte-identical HLO text —
    `make artifacts` is reproducible."""
    text1, meta1 = aot.lower_matmul(16)
    text2, _ = aot.lower_matmul(16)
    assert text1 == text2
    committed = open(os.path.join(ARTIFACTS, meta1["file"] if "file" in meta1
                                  else "matmul_16.hlo.txt")).read()
    assert text1 == committed


def test_abm_relower_matches_committed():
    text, _ = aot.lower_abm(16, 2, 24)
    committed = open(os.path.join(ARTIFACTS, "abm_p16_h2_t24.hlo.txt")).read()
    assert text == committed
