"""L2 correctness: the whole-run ABM model (lax.scan over the kernel) and
the matmul model wrapper."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_enable_x64", False)


def run(seed, p=16, h=2, t=24, **overrides):
    fn = model.abm_run_fn(p, h, t)
    params = model.default_abm_params(**overrides)
    (series,) = jax.jit(fn)(jnp.int32(seed), params)
    return np.asarray(series)


def test_series_shape_and_columns():
    s = run(0)
    assert s.shape == (24, len(model.METRIC_NAMES))


def test_determinism_per_seed():
    np.testing.assert_array_equal(run(7), run(7))
    assert not np.array_equal(run(7), run(8))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_population_conserved(seed):
    """S + C + D == n_patients at every step."""
    p = 32
    s = run(seed, p=p, h=4, t=24)
    totals = s[:, 0] + s[:, 1] + s[:, 2]
    np.testing.assert_allclose(totals, p)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bounded_metrics(seed):
    s = run(seed)
    assert (s[:, 3] >= 0).all() and (s[:, 3] <= 1).all()  # room contam
    assert (s[:, 4] >= 0).all() and (s[:, 4] <= 1).all()  # hcw contam
    assert (s[:, 5] >= 0).all() and (s[:, 5] <= 16).all() # on antibiotics


def test_transmission_parameter_has_effect():
    """An aggressive parameterization infects more than a protective one
    (averaged over seeds)."""
    def mean_carriers(**ov):
        vals = [run(s, p=64, h=8, t=72, **ov)[-1, 1:3].sum() for s in range(5)]
        return float(np.mean(vals))

    protective = mean_carriers(beta=0.05, hygiene=0.95, clean=0.9)
    aggressive = mean_carriers(beta=1.2, hygiene=0.05, clean=0.05)
    assert aggressive > protective, (aggressive, protective)


def test_default_params_and_overrides():
    p = model.default_abm_params()
    assert p.shape == (len(model.PARAM_NAMES),)
    p2 = model.default_abm_params(beta=0.9)
    assert float(p2[0]) == pytest.approx(0.9)
    with pytest.raises(KeyError):
        model.default_abm_params(nope=1.0)


def test_matmul_fn_wraps_kernel():
    x = jnp.asarray(np.random.RandomState(0).randn(32, 32), jnp.float32)
    (out,) = model.matmul_fn(x, x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x) @ np.asarray(x), rtol=1e-4, atol=1e-4
    )
