"""L1 correctness: the ensemble-statistics Pallas kernel vs the jnp
oracle, across replicate counts, series lengths, and block sizes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.reduce import ensemble_stats, vmem_footprint_bytes
from compile.kernels.ref import ensemble_stats_ref

jax.config.update("jax_enable_x64", False)


def _stack(r, t, m, seed):
    return np.random.RandomState(seed).randn(r, t, m).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    r=st.sampled_from([1, 2, 5, 25]),
    t=st.sampled_from([1, 8, 24, 168]),
    m=st.sampled_from([1, 6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref(r, t, m, seed):
    x = jnp.asarray(_stack(r, t, m, seed))
    got = ensemble_stats(x)
    want = ensemble_stats_ref(x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=8, deadline=None)
@given(bt=st.sampled_from([1, 4, 8, 24]))
def test_block_size_invariance(bt):
    x = jnp.asarray(_stack(5, 24, 6, 3))
    got = ensemble_stats(x, bt=bt)
    want = ensemble_stats_ref(x)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


def test_known_values():
    # replicates [0, 2] at every (t, m): mean 1, var 2, min 0, max 2
    x = jnp.stack([jnp.zeros((4, 3)), jnp.full((4, 3), 2.0)])
    out = np.asarray(ensemble_stats(x))
    np.testing.assert_allclose(out[..., 0], 1.0)
    np.testing.assert_allclose(out[..., 1], 2.0)
    np.testing.assert_allclose(out[..., 2], 0.0)
    np.testing.assert_allclose(out[..., 3], 2.0)


def test_single_replicate_var_zero():
    x = jnp.asarray(_stack(1, 8, 2, 0))
    out = np.asarray(ensemble_stats(x))
    np.testing.assert_allclose(out[..., 1], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[..., 0], np.asarray(x)[0], rtol=1e-6)


def test_vmem_estimate():
    # the §6 shape easily fits VMEM
    assert vmem_footprint_bytes(25, 32, 6) < 16 * 2**20
