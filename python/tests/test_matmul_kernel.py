"""L1 correctness: Pallas tiled matmul vs the pure-jnp oracle.

hypothesis sweeps shapes, block sizes, and dtypes; assert_allclose against
ref.matmul_ref is the CORE correctness signal for the kernel that every
matmul artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (
    matmul,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import matmul_ref

jax.config.update("jax_enable_x64", False)


def _rand(shape, seed, dtype=np.float32):
    return np.random.RandomState(seed).randn(*shape).astype(dtype)


# powers of two cover every study size class without 16k-scale runtimes
DIMS = st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128, 256])


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_across_shapes(m, k, n, seed):
    x = jnp.asarray(_rand((m, k), seed))
    y = jnp.asarray(_rand((k, n), seed + 1))
    got = matmul(x, y)
    want = matmul_ref(x, y)
    # Tiled k-blocked accumulation reorders f32 sums vs the one-shot dot;
    # error grows ~sqrt(k) ulps, so scale the absolute tolerance.
    atol = 1e-6 * np.sqrt(k) * 4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=atol)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([16, 32, 64, 128]),
    bn=st.sampled_from([16, 32, 64, 128]),
    bk=st.sampled_from([16, 32, 64, 128]),
)
def test_block_shape_invariance(bm, bn, bk):
    """Any tiling produces the same numbers (the kernel's key invariant)."""
    x = jnp.asarray(_rand((128, 128), 7))
    y = jnp.asarray(_rand((128, 128), 8))
    got = matmul(x, y, bm=bm, bn=bn, bk=bk)
    want = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_non_square_and_study_sizes():
    for m, k, n in [(16, 16, 16), (256, 64, 32), (512, 512, 512)]:
        x = jnp.asarray(_rand((m, k), m + k))
        y = jnp.asarray(_rand((k, n), k + n))
        np.testing.assert_allclose(
            np.asarray(matmul(x, y)), np.asarray(matmul_ref(x, y)),
            rtol=1e-4, atol=1e-4,
        )


def test_bfloat16_inputs_accumulate_in_f32():
    x = jnp.asarray(_rand((64, 64), 1)).astype(jnp.bfloat16)
    y = jnp.asarray(_rand((64, 64), 2)).astype(jnp.bfloat16)
    got = matmul(x, y)
    want = matmul_ref(x, y)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_contraction_mismatch_rejected():
    x = jnp.zeros((4, 8), jnp.float32)
    y = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(AssertionError):
        matmul(x, y)


def test_tpu_estimates():
    # DESIGN.md §8: default tiles = 192 KiB, far below 16 MiB VMEM
    assert vmem_footprint_bytes(128, 128, 128) == 3 * 128 * 128 * 4
    assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(64, 128, 128) == 0.5
    assert mxu_utilization_estimate(16, 16, 16) == (16 / 128) ** 3
