"""L1 correctness: the fused ABM ward-update kernel vs the jnp oracle,
plus the epidemiological invariants the C. difficile model must satisfy.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.abm import abm_step, vmem_footprint_bytes
from compile.kernels.ref import abm_step_ref

jax.config.update("jax_enable_x64", False)


def make_state(p, h, seed, colonized=0.15, diseased=0.05):
    rs = np.random.RandomState(seed)
    u = rs.rand(p)
    status = np.where(u < colonized, 1.0, np.where(u > 1 - diseased, 2.0, 0.0))
    return dict(
        status=status.astype(np.float32),
        antibiotic=(rs.rand(p) < 0.3).astype(np.float32) * 3.0,
        room=(rs.rand(p) * 0.3).astype(np.float32),
        hcw=(rs.rand(h) * 0.2).astype(np.float32),
        visits=(rs.rand(h, p) < 0.2).astype(np.float32),
        u_col=rs.rand(p).astype(np.float32),
    )


def default_params(**over):
    base = dict(beta=0.35, alpha=1.5, sigma=0.25, clean=0.35, hygiene=0.55,
                gamma=0.20, prog=0.03, pad=0.0)
    base.update(over)
    return np.array(list(base.values()), dtype=np.float32)


def run_both(state, params):
    args = [jnp.asarray(state[k]) for k in
            ("status", "antibiotic", "room", "hcw", "visits", "u_col")]
    args.append(jnp.asarray(params))
    return abm_step(*args), abm_step_ref(*args)


@settings(max_examples=25, deadline=None)
@given(
    p=st.sampled_from([4, 16, 33, 64, 128]),
    h=st.sampled_from([1, 2, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    beta=st.floats(0.0, 2.0),
    hygiene=st.floats(0.0, 1.0),
)
def test_kernel_matches_ref(p, h, seed, beta, hygiene):
    state = make_state(p, h, seed)
    params = default_params(beta=beta, hygiene=hygiene)
    got, want = run_both(state, params)
    for g, w, name in zip(got, want, ("status", "room", "hcw")):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6,
            err_msg=name,
        )


@settings(max_examples=20, deadline=None)
@given(p=st.sampled_from([16, 64]), seed=st.integers(0, 2**31 - 1))
def test_invariants(p, seed):
    """States stay in {0,1,2}; contamination stays in [0,1]; statuses only
    move forward (S→C→D) within a step."""
    state = make_state(p, 8, seed)
    (status, room, hcw), _ = run_both(state, default_params())
    status, room, hcw = map(np.asarray, (status, room, hcw))
    assert set(np.unique(status)).issubset({0.0, 1.0, 2.0})
    assert (room >= 0).all() and (room <= 1).all()
    assert (hcw >= 0).all() and (hcw <= 1).all()
    # no backward transitions within a kernel step
    assert (status >= state["status"]).all()


def test_no_transmission_when_beta_zero():
    state = make_state(64, 8, 3)
    params = default_params(beta=0.0, prog=0.0)
    (status, _, _), _ = run_both(state, params)
    np.testing.assert_array_equal(np.asarray(status), state["status"])


def test_beta_monotonicity():
    """Higher transmission rate ⇒ at least as many colonizations (same
    uniforms — a coupling argument)."""
    state = make_state(256, 8, 11)
    lo, _ = run_both(state, default_params(beta=0.1))
    hi, _ = run_both(state, default_params(beta=1.5))
    n_lo = float(jnp.sum(lo[0] >= 0.5))
    n_hi = float(jnp.sum(hi[0] >= 0.5))
    assert n_hi >= n_lo


def test_full_hygiene_clears_hcw_pickup_decay():
    state = make_state(32, 4, 5)
    state["visits"] = np.zeros_like(state["visits"])  # no visits
    (_, _, hcw), _ = run_both(state, default_params(hygiene=1.0))
    np.testing.assert_allclose(np.asarray(hcw), 0.0, atol=1e-7)


def test_vmem_estimate_small():
    # whole-ward state fits VMEM easily even at 4x the study size
    assert vmem_footprint_bytes(256, 32) < 16 * 2**20
