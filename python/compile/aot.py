"""AOT compile path: lower every workload variant to HLO TEXT artifacts.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the Rust side's XLA
(xla_extension 0.5.1, via the `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`). `HloModuleProto::from_text_file` reassigns ids,
so text round-trips cleanly. See /opt/xla-example/load_hlo.

Usage:  cd python && python -m compile.aot --outdir ../artifacts

Emits:
  artifacts/<name>.hlo.txt      one per workload variant
  artifacts/manifest.json       registry the Rust runtime loads at startup

Lowering is with return_tuple=True, so every artifact's output is a 1-tuple
(the Rust side unwraps with to_tuple1()).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul as matmul_kernel
from .kernels import abm as abm_kernel
from .kernels import reduce as reduce_kernel

# Matrix sizes compiled to artifacts. The paper's study enumerates
# 16..16384; we compile the sizes that are practical to *execute* on this
# host — the full 88-instance grid is still enumerated by the Rust side
# (Fig 6), with sizes above the cap routed to the native-matmul task.
MATMUL_SIZES = (16, 32, 64, 128, 256, 512)

# Ward geometries: (n_patients, n_hcw, n_steps). t168 = one week hourly
# (the paper's NetLogo runs were ~30 min; ours are seconds, the *task
# shape* is what matters to PaPaS).
ABM_VARIANTS = (
    (16, 2, 24),    # tiny: python/rust test variant
    (32, 4, 72),    # small sweep variant
    (64, 8, 168),   # the §6 case-study variant (25 instances swept)
)

# Ensemble-aggregation variants (replicates, steps) over the 6 ABM
# metrics: one per ABM variant's sweep shape.
ENSEMBLE_VARIANTS = (
    (5, 24),     # tiny
    (5, 72),     # the cdiff_intervention sweep (5 seeds)
    (25, 168),   # the §6 25-replicate sweep
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype) -> dict:
    return {"shape": list(shape), "dtype": str(dtype)}


def lower_matmul(n: int) -> tuple[str, dict]:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    lowered = jax.jit(model.matmul_fn).lower(spec, spec)
    meta = {
        "kind": "matmul",
        "size": n,
        "inputs": [_spec((n, n), "f32"), _spec((n, n), "f32")],
        "outputs": [_spec((n, n), "f32")],
        "flops": 2 * n * n * n,
        "tpu_estimate": {
            "vmem_bytes": matmul_kernel.vmem_footprint_bytes(
                min(n, 128), min(n, 128), min(n, 128)
            ),
            "mxu_utilization": matmul_kernel.mxu_utilization_estimate(
                min(n, 128), min(n, 128), min(n, 128)
            ),
        },
    }
    return to_hlo_text(lowered), meta


def lower_abm(n_patients: int, n_hcw: int, n_steps: int) -> tuple[str, dict]:
    run = model.abm_run_fn(n_patients, n_hcw, n_steps)
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    params = jax.ShapeDtypeStruct((len(model.PARAM_NAMES),), jnp.float32)
    lowered = jax.jit(run).lower(seed, params)
    meta = {
        "kind": "abm",
        "n_patients": n_patients,
        "n_hcw": n_hcw,
        "n_steps": n_steps,
        "inputs": [_spec((), "i32"), _spec((len(model.PARAM_NAMES),), "f32")],
        "outputs": [_spec((n_steps, len(model.METRIC_NAMES)), "f32")],
        "param_names": list(model.PARAM_NAMES),
        "metric_names": list(model.METRIC_NAMES),
        "tpu_estimate": {
            "vmem_bytes": abm_kernel.vmem_footprint_bytes(n_patients, n_hcw),
        },
    }
    return to_hlo_text(lowered), meta


def lower_ensemble(replicates: int, n_steps: int) -> tuple[str, dict]:
    m = len(model.METRIC_NAMES)
    spec = jax.ShapeDtypeStruct((replicates, n_steps, m), jnp.float32)
    lowered = jax.jit(model.ensemble_fn).lower(spec)
    meta = {
        "kind": "ensemble",
        "replicates": replicates,
        "n_steps": n_steps,
        "inputs": [_spec((replicates, n_steps, m), "f32")],
        "outputs": [_spec((n_steps, m, 4), "f32")],
        "stat_names": list(reduce_kernel.STAT_NAMES),
        "metric_names": list(model.METRIC_NAMES),
        "tpu_estimate": {
            "vmem_bytes": reduce_kernel.vmem_footprint_bytes(
                replicates, min(n_steps, 32), m
            ),
        },
    }
    return to_hlo_text(lowered), meta


def build_all(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"format": 1, "artifacts": {}}

    jobs = []
    for n in MATMUL_SIZES:
        jobs.append((f"matmul_{n}", lambda n=n: lower_matmul(n)))
    for p, h, t in ABM_VARIANTS:
        jobs.append(
            (f"abm_p{p}_h{h}_t{t}", lambda p=p, h=h, t=t: lower_abm(p, h, t))
        )
    for r, t in ENSEMBLE_VARIANTS:
        jobs.append(
            (f"ensemble_r{r}_t{t}", lambda r=r, t=t: lower_ensemble(r, t))
        )

    for name, build in jobs:
        text, meta = build()
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = fname
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        meta["hlo_bytes"] = len(text)
        manifest["artifacts"][name] = meta
        print(f"  {name}: {len(text)} chars -> {fname}")

    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  manifest: {len(manifest['artifacts'])} artifacts -> {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.outdir)


if __name__ == "__main__":
    main()
