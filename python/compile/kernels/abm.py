"""L1 Pallas kernel: C. difficile ward transmission step (NetLogo substitute).

The paper's §6 case study sweeps a NetLogo agent-based model of C. difficile
transmission in a healthcare ward (healthcare workers as vectors, per-room
contamination, patient antibiotic histories). NetLogo iterates per-turtle;
the TPU-idiomatic formulation vectorizes the per-agent update across the
patient axis and expresses the HCW<->patient interaction as two small
matvecs against the visit matrix (H x P) — exactly the part NetLogo does
with nested ask-loops.

The kernel computes ONE epidemic step given pre-drawn uniforms (randomness
stays in L2 where jax.random threefry lives); it is a single-block kernel:
ward sizes (P <= a few hundred) fit VMEM whole, so grid=() and the BlockSpec
machinery is unnecessary — the win is fusing the whole update into one pass.

interpret=True always (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _abm_step_kernel(
    status_ref, anti_ref, room_ref, hcw_ref, visits_ref, u_ref, params_ref,
    new_status_ref, new_room_ref, new_hcw_ref,
):
    """Fused one-pass ward update; semantics identical to ref.abm_step_ref."""
    status = status_ref[...]
    room = room_ref[...]
    hcw = hcw_ref[...]
    visits = visits_ref[...]
    u = u_ref[...]
    beta = params_ref[0]
    alpha = params_ref[1]
    sigma = params_ref[2]
    clean = params_ref[3]
    hygiene = params_ref[4]
    gamma = params_ref[5]
    prog = params_ref[6]

    # exposure[p] = sum_h visits[h, p] * hcw[h]   (V^T @ hcw)
    exposure = jnp.sum(visits * hcw[:, None], axis=0)
    suscept = jnp.where(
        status < 0.5, 1.0 + alpha * (anti_ref[...] > 0.0), 0.0
    )
    p_col = 1.0 - jnp.exp(-beta * (exposure + room))
    colonize = (u < p_col * suscept) & (status < 0.5)
    progress = (u < prog) & (status >= 0.5) & (status < 1.5)
    new_status = jnp.where(colonize, 1.0, jnp.where(progress, 2.0, status))

    shed = sigma * (new_status >= 0.5)
    new_room = jnp.clip(room * (1.0 - clean) + shed, 0.0, 1.0)

    # pickup[h] = sum_p visits[h, p] * (room[p] + gamma * carrier[p])
    load = room + gamma * (new_status >= 0.5)
    pickup = jnp.sum(visits * load[None, :], axis=1)
    new_hcw = jnp.clip(hcw * (1.0 - hygiene) + pickup, 0.0, 1.0)

    new_status_ref[...] = new_status
    new_room_ref[...] = new_room
    new_hcw_ref[...] = new_hcw


@jax.jit
def abm_step(status, antibiotic, room, hcw, visits, u_col, params):
    """One ward step via the fused Pallas kernel. See ref.abm_step_ref."""
    p = status.shape[0]
    h = hcw.shape[0]
    return pl.pallas_call(
        _abm_step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
        ),
        interpret=True,
    )(status, antibiotic, room, hcw, visits, u_col, params)


def vmem_footprint_bytes(n_patients: int, n_hcw: int) -> int:
    """Whole-ward VMEM residency: all state + visit matrix + outputs (f32)."""
    per_p = 5  # status, antibiotic, room, uniforms, new_status/new_room amortized
    return 4 * (
        per_p * n_patients + 2 * n_hcw + n_hcw * n_patients + 8
    )
