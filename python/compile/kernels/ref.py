"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: python/tests/ asserts the Pallas
kernels (interpret=True) match these within float tolerance across shape /
dtype / seed sweeps (hypothesis). They are also what the L2 model *means*;
the kernels are just the fast path.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain jnp matmul with f32 accumulation (matches the kernel's acc)."""
    return jnp.dot(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def ensemble_stats_ref(x):
    """[R, T, M] replicate stack → [T, M, 4] (mean, var, min, max)."""
    x = x.astype(jnp.float32)
    r = x.shape[0]
    mean = jnp.mean(x, axis=0)
    denom = max(r - 1, 1)
    var = jnp.sum((x - mean[None]) ** 2, axis=0) / denom
    return jnp.stack(
        [mean, var, jnp.min(x, axis=0), jnp.max(x, axis=0)], axis=-1
    )


def abm_step_ref(status, antibiotic, room, hcw, visits, u_col, params):
    """One C. difficile ward transmission step — reference semantics.

    Args:
      status:     f32[P]   0=susceptible, 1=colonized, 2=diseased
      antibiotic: f32[P]   days of antibiotic exposure remaining (>=0)
      room:       f32[P]   room contamination level in [0, 1]
      hcw:        f32[H]   healthcare-worker hand contamination in [0, 1]
      visits:     f32[H,P] 1.0 where HCW h visits patient p this step
      u_col:      f32[P]   uniform(0,1) draws for colonization events
      params:     f32[8]   [beta, alpha, sigma, clean, hygiene, gamma,
                            prog, pad] — transmission rate, antibiotic
                            susceptibility multiplier, shedding rate, room
                            cleaning efficacy, HCW hand-hygiene compliance,
                            patient->HCW pickup factor, colonized->diseased
                            progression probability, padding.

    Returns:
      (new_status f32[P], new_room f32[P], new_hcw f32[H])
    """
    beta, alpha, sigma, clean, hygiene, gamma, prog = (
        params[0], params[1], params[2], params[3], params[4], params[5],
        params[6],
    )
    # Exposure delivered to each patient by visiting HCWs:  V^T @ hcw.
    exposure = jnp.einsum("hp,h->p", visits, hcw)
    # Antibiotic exposure raises susceptibility of susceptible patients.
    suscept = jnp.where(status < 0.5, 1.0 + alpha * (antibiotic > 0.0), 0.0)
    p_col = 1.0 - jnp.exp(-beta * (exposure + room))
    colonize = (u_col < p_col * suscept) & (status < 0.5)
    # Susceptible -> colonized via the transmission draw; colonized ->
    # diseased when the same uniform falls below prog (one-pass kernel).
    progress = (u_col < prog) & (status >= 0.5) & (status < 1.5)
    new_status = jnp.where(
        colonize, 1.0, jnp.where(progress, 2.0, status)
    )
    # Shedding into the room by colonized/diseased patients; rooms cleaned.
    shed = sigma * (new_status >= 0.5)
    new_room = jnp.clip(room * (1.0 - clean) + shed, 0.0, 1.0)
    # HCWs pick up from rooms + patients they visited; then hand hygiene.
    pickup = jnp.einsum("hp,p->h", visits, room + gamma * (new_status >= 0.5))
    new_hcw = jnp.clip(hcw * (1.0 - hygiene) + pickup, 0.0, 1.0)
    return new_status, new_room, new_hcw
