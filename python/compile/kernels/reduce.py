"""L1 Pallas kernel: replicate-ensemble statistics (the paper's "data
aggregation" workflow structure, §1 / Bharathi et al.).

A parameter sweep produces R replicate metric series of shape [T, M]
(e.g. the 25 C. difficile runs of §6). The aggregation stage reduces the
stack [R, T, M] to per-step ensemble statistics [T, M, 4]:
mean, unbiased variance, min, max — Welford-free one-pass moments are fine
in f32 at R ≤ a few hundred.

Kernel shape: grid over T-blocks; each step loads an [R, bt, M] slab into
VMEM, reduces over the replicate axis in one fused pass. This is the
post-processing hot-spot PaPaS pipelines run after a sweep (the `abm-agg`
builtin task on the Rust side).

interpret=True always (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Statistic columns emitted per (step, metric).
STAT_NAMES = ("mean", "var", "min", "max")


def _ensemble_kernel(x_ref, o_ref):
    """Reduce an [R, bt, M] slab over axis 0 → [bt, M, 4]."""
    x = x_ref[...]
    r = x.shape[0]
    mean = jnp.mean(x, axis=0)
    # unbiased sample variance (guard r == 1)
    diff = x - mean[None, :, :]
    denom = jnp.maximum(r - 1, 1)
    var = jnp.sum(diff * diff, axis=0) / denom
    o_ref[..., 0] = mean
    o_ref[..., 1] = var
    o_ref[..., 2] = jnp.min(x, axis=0)
    o_ref[..., 3] = jnp.max(x, axis=0)


def _pick_block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bt",))
def ensemble_stats(x, *, bt: int = 32):
    """[R, T, M] replicate stack → [T, M, 4] per-step ensemble stats."""
    r, t, m = x.shape
    assert r >= 1, "need at least one replicate"
    bt = _pick_block(t, bt)
    grid = (t // bt,)
    return pl.pallas_call(
        _ensemble_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((r, bt, m), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((bt, m, 4), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, 4), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))


def vmem_footprint_bytes(r: int, bt: int, m: int) -> int:
    """Slab + output tile residency per grid step (f32)."""
    return 4 * (r * bt * m + bt * m * 4)
