"""L1 Pallas kernel: tiled matrix multiply (the paper's §7 workload).

The paper's performance study sweeps an OpenMP matmul over matrix sizes and
thread counts. Our workload equivalent is a TPU-idiomatic Pallas matmul:

  * grid over (M/bm, N/bn) output tiles with a K-loop as the innermost grid
    axis, accumulating into a VMEM scratch accumulator;
  * BlockSpec tiles sized for VMEM residency (default 128x128x128 f32 ->
    3 * 64 KiB = 192 KiB, far below ~16 MiB VMEM);
  * MXU-shaped inner `jnp.dot` with preferred_element_type=float32 so
    bf16/f32 inputs both accumulate in f32.

Hardware adaptation note (DESIGN.md section 4): the paper targets CPU/OpenMP,
not GPU, so there is no warp/threadblock construct to port; we express the
HBM<->VMEM schedule with BlockSpec instead of OMP scheduling clauses.

interpret=True ALWAYS: the CPU PJRT plugin cannot run Mosaic custom-calls;
interpret mode lowers to plain HLO so the Rust runtime can execute the
artifact anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += x_tile @ y_tile; flush on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (dims here are powers of 2)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = X @ Y via the tiled Pallas kernel (interpret mode).

    Shapes need not be tile-aligned: block sizes are clamped to divisors of
    each dimension (all study sizes are powers of two, so blocks stay
    MXU-friendly powers of two).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, y)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Estimated VMEM residency per grid step: x-tile + y-tile + acc tile.

    Used by DESIGN.md section 8 / EXPERIMENTS.md to report the TPU estimate
    (interpret mode gives no real TPU timings).
    """
    return (bm * bk + bk * bn + bm * bn) * itemsize


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of each inner dot that maps onto full 128x128 MXU passes."""
    eff = 1.0
    for b in (bm, bn, bk):
        eff *= min(b, 128) / 128.0
    return eff
