"""L2: the swept workloads as JAX compute graphs (build-time only).

Two workloads, matching the paper's two case studies:

  * `matmul_fn` — §7 performance-study workload (OpenMP matmul
    substitute). Calls the L1 Pallas tiled-matmul kernel so the kernel
    lowers into the same HLO artifact.
  * `abm_run_fn(P, H, T)` — §6 parameter-sweep workload: the C. difficile
    healthcare-ward agent-based model (NetLogo substitute). `lax.scan` over
    T steps; each step draws visit patterns / uniforms with threefry
    counters and applies the L1 fused ward-update kernel. Returns a metrics
    time series — a single tensor so the Rust runtime deals with exactly
    one output buffer.

Everything here is lowered ONCE by aot.py into artifacts/*.hlo.txt; Python
never runs on the Rust request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.abm import abm_step
from .kernels.matmul import matmul
from .kernels.reduce import ensemble_stats

# Index names for the ABM params vector (f32[8]).
PARAM_NAMES = (
    "beta",       # transmission rate per unit exposure
    "alpha",      # antibiotic susceptibility multiplier
    "sigma",      # shedding rate of carriers into rooms
    "clean",      # per-step room cleaning efficacy
    "hygiene",    # HCW hand-hygiene compliance
    "gamma",      # patient->HCW pickup factor
    "prog",       # colonized -> diseased progression probability
    "visit_rate"  # per-(HCW, patient) visit probability per step
)

# Metrics columns emitted per step (f32[T, 6]).
METRIC_NAMES = (
    "n_susceptible", "n_colonized", "n_diseased",
    "mean_room_contam", "mean_hcw_contam", "n_on_antibiotics",
)


def matmul_fn(x, y):
    """C = X @ Y through the Pallas kernel (1-tuple for AOT).

    Block-size policy (perf pass, EXPERIMENTS.md §Perf): 128³ tiles keep
    the MXU shape, but on the interpret/CPU path every grid step pays a
    dispatch overhead — at n=512 that is 64 steps and the HLO artifact ran
    1.7× slower than the native baseline. 256³ tiles (768 KiB VMEM, still
    ≪16 MiB; two full MXU passes per axis) cut n=512 to 8 steps.
    """
    n = max(x.shape[0], x.shape[1])
    b = 256 if n >= 256 else 128
    return (matmul(x, y, bm=b, bn=b, bk=b),)


def _metrics(status, antibiotic, room, hcw):
    return jnp.stack([
        jnp.sum(status < 0.5).astype(jnp.float32),
        jnp.sum((status >= 0.5) & (status < 1.5)).astype(jnp.float32),
        jnp.sum(status >= 1.5).astype(jnp.float32),
        jnp.mean(room),
        jnp.mean(hcw),
        jnp.sum(antibiotic > 0.0).astype(jnp.float32),
    ])


def abm_init(key, n_patients: int, n_hcw: int, init_colonized: float,
             init_antibiotic: float):
    """Initial ward state: a few admitted carriers, some on antibiotics."""
    k1, k2 = jax.random.split(key)
    status = (
        jax.random.uniform(k1, (n_patients,)) < init_colonized
    ).astype(jnp.float32)
    antibiotic = jnp.where(
        jax.random.uniform(k2, (n_patients,)) < init_antibiotic, 3.0, 0.0
    )
    room = jnp.zeros((n_patients,), jnp.float32)
    hcw = jnp.zeros((n_hcw,), jnp.float32)
    return status, antibiotic, room, hcw


def abm_scan_step(carry, key, params, n_patients: int, n_hcw: int):
    """One epidemic step: draw stochastic inputs, run the fused kernel,
    then the slow-timescale updates (antibiotic countdown, admissions)."""
    status, antibiotic, room, hcw = carry
    kv, ku, ka, kd = jax.random.split(key, 4)
    visit_rate = params[7]
    visits = (
        jax.random.uniform(kv, (n_hcw, n_patients)) < visit_rate
    ).astype(jnp.float32)
    u_col = jax.random.uniform(ku, (n_patients,))

    status, room, hcw = abm_step(
        status, antibiotic, room, hcw, visits, u_col, params
    )

    # Antibiotic courses: countdown + new prescriptions (fixed 5% / step).
    new_rx = jax.random.uniform(ka, (n_patients,)) < 0.05
    antibiotic = jnp.where(new_rx, 3.0, jnp.maximum(antibiotic - 1.0, 0.0))

    # Discharge/admission: 2% of carriers replaced by a fresh susceptible
    # admission; their room gets a terminal clean.
    discharge = (jax.random.uniform(kd, (n_patients,)) < 0.02) & (
        status >= 0.5
    )
    status = jnp.where(discharge, 0.0, status)
    antibiotic = jnp.where(discharge, 0.0, antibiotic)
    room = jnp.where(discharge, room * 0.1, room)

    carry = (status, antibiotic, room, hcw)
    return carry, _metrics(status, antibiotic, room, hcw)


def abm_run_fn(n_patients: int, n_hcw: int, n_steps: int):
    """Build the whole-run function for fixed ward geometry.

    Returns fn(seed i32[], params f32[8]) -> (metrics f32[T, 6],)
    """

    def run(seed, params):
        key = jax.random.PRNGKey(seed)
        k_init, k_run = jax.random.split(key)
        carry = abm_init(k_init, n_patients, n_hcw,
                         init_colonized=0.10, init_antibiotic=0.30)
        keys = jax.random.split(k_run, n_steps)
        _, series = jax.lax.scan(
            lambda c, k: abm_scan_step(c, k, params, n_patients, n_hcw),
            carry, keys,
        )
        return (series,)

    return run


def ensemble_fn(x):
    """Aggregation workload: replicate stack → per-step ensemble stats
    (1-tuple for AOT). The sweep post-processing stage of §1's "data
    aggregation" workflow structure."""
    return (ensemble_stats(x),)


def default_abm_params(**overrides) -> jnp.ndarray:
    """Baseline parameterization; keyword overrides by PARAM_NAMES."""
    base = dict(beta=0.35, alpha=1.5, sigma=0.25, clean=0.35, hygiene=0.55,
                gamma=0.20, prog=0.03, visit_rate=0.12)
    for k, v in overrides.items():
        if k not in base:
            raise KeyError(f"unknown ABM parameter {k!r}")
        base[k] = v
    return jnp.array([base[k] for k in PARAM_NAMES], jnp.float32)
