//! Quickstart: load a parameter file, inspect the combination space, run
//! it on the local executor, and read the provenance.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use papas::study::Study;
use papas::viz::{render_ascii, DagView};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A small self-contained study: sweep two parameters of a shell task.
    let dir = std::env::temp_dir().join("papas_quickstart");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let study_file = dir.join("hello.yaml");
    std::fs::write(
        &study_file,
        "hello:\n  \
           name: Hello parameter study\n  \
           who: [world, papas]\n  \
           n: [1, 2, 3]\n  \
           command: /bin/sh -c \"echo run-${n} hello ${who}\"\n",
    )?;

    let study = Study::from_file(&study_file)?.with_db_root(dir.join(".papas"));
    println!(
        "study '{}': {} parameters, {} combinations",
        study.name,
        study.space().params().len(),
        study.space().len()
    );

    // Enumerate the workflow instances (what Figure 6 shows for matmul),
    // streamed one at a time from the lazy source.
    for inst in study.source().iter() {
        let inst = inst?;
        println!("  {} -> {}", inst.display_id(), inst.command_lines()[0]);
    }

    // The task DAG (single node here) — materialize just one instance.
    let first = study.instance_at(0)?;
    println!("\ntask graph:\n{}", render_ascii(&DagView::pending(&first.dag)));

    // Run on 2 local workers.
    let report = study.run_local(2)?;
    println!(
        "done: {} completed, makespan {:.3}s, utilization {:.0}%",
        report.completed,
        report.makespan,
        report.utilization * 100.0
    );
    assert!(report.all_ok());

    // Provenance lives in the file database.
    println!("\nprovenance: {}", study.db_root.join("records.jsonl").display());
    Ok(())
}
