//! Checkpoint/restart (§4.1): pause a study mid-way (here: fail half the
//! tasks on purpose), then resume — only the unfinished work re-runs.
//!
//! ```text
//! cargo run --release --example checkpoint_restart
//! ```

use papas::study::Study;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("papas_ckpt_example");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // Tasks 1..6 sleep; tasks where marker file is absent fail on the
    // first run (simulating a fault), succeed on the second.
    let marker = dir.join("recovered");
    let study_file = dir.join("faulty.yaml");
    std::fs::write(
        &study_file,
        format!(
            "work:\n  \
               n: [1, 2, 3, 4, 5, 6]\n  \
               command: /bin/sh -c \"test $((${{n}} % 2)) -eq 0 || test -f {} \"\n",
            marker.display()
        ),
    )?;

    let study = Study::from_file(&study_file)?.with_db_root(dir.join(".papas"));
    println!("run 1 (half the tasks fault):");
    let r1 = study.run_local(2)?;
    println!(
        "  completed={} failed={} (checkpoint keeps the {} successes)",
        r1.completed, r1.failed, r1.completed
    );
    assert_eq!(r1.completed, 3);
    assert_eq!(r1.failed, 3);

    // "Fix the environment" and resume: only the 3 failures re-run.
    std::fs::write(&marker, "ok")?;
    println!("run 2 (resume from checkpoint):");
    let r2 = study.run_local(2)?;
    println!(
        "  completed={} restored={} failed={}",
        r2.completed, r2.restored, r2.failed
    );
    assert_eq!(r2.restored, 3, "previous successes restored, not re-run");
    assert_eq!(r2.completed, 3, "only the failures re-ran");
    assert!(r2.all_ok());

    // A third run does nothing at all.
    let r3 = study.run_local(2)?;
    assert_eq!(r3.restored, 6);
    assert_eq!(r3.completed, 0);
    println!("run 3: fully restored, nothing executed — checkpoint complete.");
    Ok(())
}
