//! The §7 performance study: weak/strong scaling of the matmul workload.
//!
//! Part 1 parses the paper's verbatim Figure 5 file and enumerates all 88
//! workflow instances (Figure 6). Part 2 executes the execution-scaled
//! variant (sizes ≤ 512 — this is a 1-core host) and prints the per-size,
//! per-thread-count runtimes that a scaling study reports, using the HLO
//! (Pallas) path where artifacts exist and the native path beyond.
//!
//! ```text
//! make artifacts && cargo run --release --example matmul_scaling
//! ```

use papas::bench::{fmt_secs, Table};
use papas::runtime::RuntimeService;
use papas::study::Study;
use std::collections::BTreeMap;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- Part 1: the paper's exact file → the 88 instances of Fig 6 ----
    let full = Study::from_file("studies/matmul_omp.yaml")?;
    let instances = full.instances()?;
    println!(
        "Figure 5 file parsed: {} combinations ({} sizes × {} thread counts)",
        instances.len(),
        11,
        8
    );
    assert_eq!(instances.len(), 88, "the paper's 88 executions");
    println!("first and last instances (Figure 6 content):");
    println!("  {}", instances.first().unwrap().command_lines()[0]);
    println!("  {}", instances.last().unwrap().command_lines()[0]);

    // ---- Part 2: execute the scaled study ------------------------------
    let work = std::env::temp_dir().join("papas_matmul_scaling");
    let _ = std::fs::remove_dir_all(&work);
    let study = Study::from_file("studies/matmul_omp_small.yaml")?
        .with_db_root(work.join(".papas"))
        .with_runtime(RuntimeService::start("artifacts")?);
    println!(
        "\nexecuting scaled study: {} instances (sizes ≤ 512)",
        study.n_instances()
    );
    let report = study.run_local(2)?;
    assert!(report.all_ok());

    // Aggregate task runtimes by (size, threads) from provenance records.
    let mut by_key: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    for rec in &report.records {
        let combo = study.space().combination(rec.instance)?;
        let size = combo["matmulOMP:args:size"].as_i64().unwrap() as u64;
        let threads =
            combo["matmulOMP:environ:OMP_NUM_THREADS"].as_i64().unwrap() as u64;
        by_key.insert((size, threads), rec.duration());
    }

    let mut table = Table::new(
        "matmul scaling (seconds per task; columns = OMP_NUM_THREADS)",
        &["size", "T=1", "T=2", "T=4", "T=8"],
    );
    let sizes: Vec<u64> = vec![16, 32, 64, 128, 256, 512];
    for &s in &sizes {
        let cell = |t: u64| {
            by_key
                .get(&(s, t))
                .map(|d| fmt_secs(*d))
                .unwrap_or_else(|| "-".into())
        };
        table.row(&[s.to_string(), cell(1), cell(2), cell(4), cell(8)]);
    }
    table.print();
    println!(
        "\ntotal: {} tasks, makespan {}, utilization {:.0}%",
        report.completed,
        fmt_secs(report.makespan),
        report.utilization * 100.0
    );
    Ok(())
}
