//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the paper's §6 case study on
//! the full three-layer stack.
//!
//! 25 replicates of the C. difficile ward model (the NetLogo substitute)
//! run as ONE grouped job through the MPI-style dispatcher — the PaPaS
//! technique — under each of the paper's grouping schemes, on real PJRT
//! executions of the AOT-compiled JAX/Pallas artifact:
//!
//!   WDL file → parameter engine (25 combos) → workflow engine → MPI
//!   dispatcher (N×P ranks) → PJRT runtime (HLO artifact) → provenance.
//!
//! Prints per-scheme makespans, utilization, scheduler interactions, and
//! an epidemic summary proving the simulations computed real dynamics.
//!
//! ```text
//! make artifacts && cargo run --release --example netlogo_sweep
//! ```

use papas::bench::{fmt_secs, Table};
use papas::runtime::RuntimeService;
use papas::study::Study;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rt = RuntimeService::start("artifacts")?;
    let work = std::env::temp_dir().join("papas_netlogo_sweep");
    let _ = std::fs::remove_dir_all(&work);

    // The paper's grouping schemes (Figures 3–4).
    let schemes: &[(&str, usize, usize)] = &[
        ("1N-1P", 1, 1),
        ("1N-2P", 1, 2),
        ("2N-1P", 2, 1),
        ("2N-2P", 2, 2),
    ];

    let mut table = Table::new(
        "NetLogo-substitute sweep: 25 C.diff ward runs, grouped MPI job per scheme",
        &["scheme", "ranks", "makespan", "utilization", "sched-interactions"],
    );

    let mut final_colonized: Vec<f64> = Vec::new();
    for (name, n, p) in schemes {
        let db = work.join(format!("db_{name}"));
        let study = Study::from_file("studies/netlogo_cdiff.yaml")?
            .with_db_root(&db)
            .with_runtime(rt.clone());
        assert_eq!(study.n_instances(), 25, "the paper's 25 simulations");
        let report = study.run_mpi(*n, *p)?;
        assert!(report.all_ok(), "scheme {name} failed");
        table.row(&[
            name.to_string(),
            format!("{}", n * p),
            fmt_secs(report.makespan),
            format!("{:.0}%", report.utilization * 100.0),
            // one grouped batch job = 2 scheduler interactions (start+stop)
            "2".to_string(),
        ]);

        // Read the CSVs once to prove real epidemic dynamics ran.
        if final_colonized.is_empty() {
            for i in 0..25u64 {
                let seed = inst_seed(&study, i)?;
                let csv = db
                    .join("work")
                    .join(format!("wf-{i:04}"))
                    .join(format!("cdiff_run_{seed}.csv"));
                let text = std::fs::read_to_string(&csv)?;
                let last = text.lines().last().ok_or("empty csv")?;
                let cols: Vec<f64> = last
                    .split(',')
                    .skip(1)
                    .map(|x| x.parse().unwrap_or(0.0))
                    .collect();
                final_colonized.push(cols[1]); // n_colonized
            }
        }
    }
    table.print();

    let mean = final_colonized.iter().sum::<f64>() / final_colonized.len() as f64;
    let min = final_colonized.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = final_colonized.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "\nepidemic outcome across 25 replicates (64-patient ward, 168 h): \
         colonized at end mean={mean:.1} min={min} max={max}"
    );
    assert!(max > 0.0, "some replicate must show transmission");

    let (compiles, execs) = rt.stats()?;
    println!(
        "PJRT: {compiles} artifact compilation(s), {execs} executions \
         (compile-once cache across all schemes)"
    );
    println!("\nRecorded in EXPERIMENTS.md §E2E.");
    Ok(())
}

/// The seed value of instance `i` (its swept parameter).
fn inst_seed(study: &Study, i: u64) -> Result<String, Box<dyn std::error::Error>> {
    let combo = study.space().combination(i)?;
    Ok(combo
        .get("cdiff:seed")
        .map(|v| v.as_str().to_string())
        .ok_or("no seed param")?)
}
